"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so a
126-layer scanned stack under-reports FLOPs by ~100x.  Optimized HLO on
this backend annotates every while with
``backend_config={"known_trip_count":{"n":...}}`` — we walk the call
graph from ENTRY, multiply each computation's cost by the product of
enclosing trip counts, and account:

* FLOPs: ``dot`` ops (2 * result_numel * contraction_size); dots never
  live inside fusion bodies on this backend (verified).
* bytes: operand + result sizes of every materialising top-level op
  (fusion boundaries = kernel HBM traffic).
* collectives: result bytes per op kind, trip-scaled.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "iota",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_ONE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# shape group is lazy: the first ``word(`` after '=' is the opcode (shape
# strings never contain parens-after-word; tuple shapes may contain
# ``/*index=N*/`` comments, so ``[^=]`` would be wrong)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")


def _shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    numel_total, bytes_total = 0, 0
    for dtype, dims in _SHAPE_ONE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return numel_total, bytes_total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_ONE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instruction:
    __slots__ = ("name", "shape", "op", "line")

    def __init__(self, name, shape, op, line):
        self.name, self.shape, self.op, self.line = name, shape, op, line


def _parse_module(hlo_text: str):
    comps: Dict[str, List[Instruction]] = {}
    entry = None
    name, depth, instrs = None, 0, []
    for line in hlo_text.splitlines():
        if name is None:
            m = _COMP_HDR_RE.match(line)
            if m and "->" in line:
                name, depth, instrs = m.group(1), 1, []
                if line.lstrip().startswith("ENTRY"):
                    entry = name
            continue
        depth += line.count("{") - line.count("}")
        im = _INSTR_RE.match(line)
        if im:
            instrs.append(Instruction(im.group(1), im.group(2),
                                      im.group(3), line))
        if depth <= 0:
            comps[name] = instrs
            name = None
    return comps, entry


def _callees(instr: Instruction) -> List[Tuple[str, int, bool]]:
    """(callee, multiplier, is_fusion_body) edges out of one op."""
    line = instr.line
    out = []
    if instr.op == "while":
        trip = 1
        m = re.search(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)', line)
        if m:
            trip = int(m.group(1))
        for role in ("condition", "body"):
            mm = re.search(role + r"=%?([\w\.\-]+)", line)
            if mm:
                out.append((mm.group(1), trip, False))
    elif instr.op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", line)
        if m:
            out.append((m.group(1), 1, True))
    elif instr.op in ("call", "custom-call"):
        m = re.search(r"to_apply=%?([\w\.\-]+)", line)
        if m:
            out.append((m.group(1), 1, False))
    elif instr.op == "conditional":
        for mm in re.finditer(r"branch_computations=\{([^}]*)\}", line):
            for c in mm.group(1).split(","):
                out.append((c.strip().lstrip("%"), 1, False))
        for mm in re.finditer(r"(?:true|false)_computation=%?([\w\.\-]+)",
                              line):
            out.append((mm.group(1), 1, False))
    return out


def analyze(hlo_text: str) -> Dict[str, float]:
    comps, entry = _parse_module(hlo_text)
    shapes: Dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.shape

    # propagate execution multipliers down the call graph
    mult: Dict[str, float] = {}
    fusion_body: Dict[str, bool] = {}

    def visit(cname: str, m: float, is_fusion: bool):
        if cname not in comps:
            return
        mult[cname] = mult.get(cname, 0.0) + m
        fusion_body[cname] = fusion_body.get(cname, True) and is_fusion
        for ins in comps[cname]:
            for callee, k, fus in _callees(ins):
                visit(callee, m * k, fus)

    visit(entry, 1.0, False)

    flops = 0.0
    bytes_acc = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or fusion_body.get(cname, False):
            continue
        for ins in instrs:
            if ins.op == "dot":
                r_numel, _ = _shape_numel_bytes(ins.shape)
                lm = re.search(r"dot\(%([\w\.\-]+)", ins.line)
                k = 1
                if lm and lm.group(1) in shapes:
                    lhs_dims = _shape_dims(shapes[lm.group(1)])
                    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                   ins.line)
                    if cm and lhs_dims:
                        for ci in cm.group(1).split(","):
                            if ci:
                                k *= lhs_dims[int(ci)]
                flops += 2.0 * r_numel * k * m
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.op.endswith("-start"):
                _, b = _shape_numel_bytes(ins.shape)
                coll[base] += b * m
                coll_counts[base] += m
            if ins.op in _FREE_OPS:
                continue
            _, rb = _shape_numel_bytes(ins.shape)
            if ins.op in ("dynamic-slice", "gather"):
                # reads only the sliced region, not the whole operand
                # (a scan body slicing stacked weights would otherwise be
                # charged the full 126-layer stack every iteration)
                bytes_acc += 2.0 * rb * m
                continue
            if ins.op in ("dynamic-update-slice", "scatter"):
                # in-place buffer update: traffic ~ 2x the update operand
                op_bytes = []
                for om in re.finditer(r"%([\w\.\-]+)",
                                      ins.line.split("(", 1)[1]):
                    if om.group(1) in shapes:
                        op_bytes.append(
                            _shape_numel_bytes(shapes[om.group(1)])[1])
                upd = sorted(op_bytes)[-2] if len(op_bytes) >= 2 else rb
                bytes_acc += 2.0 * upd * m
                continue
            # HBM traffic at kernel boundary: operands + result; operands
            # that alias the result (in-place loop fusions over big
            # buffers) are charged once
            ob = 0
            seen_alias = False
            for om in re.finditer(r"%([\w\.\-]+)", ins.line.split("(", 1)[1]):
                nm = om.group(1)
                if nm in shapes:
                    _, b = _shape_numel_bytes(shapes[nm])
                    if ins.op == "fusion" and not seen_alias and b == rb \
                            and b > 1 << 20:
                        seen_alias = True
                        continue
                    ob += b
            bytes_acc += (rb + ob) * m

    out = {"flops": flops, "bytes_accessed": bytes_acc,
           "collectives": {k: v for k, v in coll.items() if v}}
    out["collectives"]["total_bytes"] = float(sum(coll.values()))
    out["collectives"]["op_counts"] = {k: v for k, v in coll_counts.items()
                                       if v}
    return out
