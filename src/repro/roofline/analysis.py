"""Roofline terms from compiled dry-run artifacts (no real TPU).

compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
memory term     = HLO_bytes / (chips x HBM_bw)
collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes are parsed out of the post-SPMD optimized HLO text
(``compiled.as_text()``): we sum the *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaling ops that live inside ``while`` loop bodies
by the loop trip count when it is statically recoverable from the scan
length.
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> byte count (0 for unparseable/tuple parts)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COLL_OP_RE = re.compile(
    r"=\s*(?P<shape>\(?[\w\[\],{}: ]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\(")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> body text (brace-balanced blocks)."""
    comps: Dict[str, str] = {}
    name, depth, buf = None, 0, []
    for line in hlo_text.splitlines():
        if name is None:
            m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
            if m and "->" in line:
                name, depth, buf = m.group(1), 1, [line]
            continue
        buf.append(line)
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[name] = "\n".join(buf)
            name = None
    return comps


def _trip_count_of_cond(cond_text: str) -> int:
    """Largest s32/u32 constant in a while condition ~ trip count."""
    best = 1
    for m in re.finditer(r"[su]32\[\]\s+constant\((\d+)\)", cond_text):
        best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of collective ops in optimized HLO.

    Collectives inside while-loop bodies (layer scans, flash-attention
    scans) are scaled by the loop trip count, recovered from the integer
    bound in the loop condition (XLA keeps scan lengths as constants
    there).  Async pairs are counted once (at the ``-done`` op).
    """
    comps = _split_computations(hlo_text)
    # trip count per body computation
    trips: Dict[str, int] = {}
    for cname, ctext in comps.items():
        for m in re.finditer(
                r"while\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)",
                ctext):
            cond, body = m.group(1), m.group(2)
            trips[body] = _trip_count_of_cond(comps.get(cond, ""))

    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for cname, ctext in comps.items():
        mult = trips.get(cname, 1)
        for m in _COLL_OP_RE.finditer(ctext):
            if m.group("variant") == "-start":
                continue  # counted at -done
            nbytes = _shape_bytes(m.group("shape"))
            totals[m.group("op")] += nbytes * mult
            counts[m.group("op")] += 1
    out: Dict[str, float] = {k: v for k, v in totals.items() if v}
    out["total_bytes"] = float(sum(totals.values()))
    out["op_counts"] = {k: v for k, v in counts.items() if v}
    return out


def memory_dict(mem) -> Dict[str, float]:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr.replace("_in_bytes", "_bytes")] = int(getattr(mem, attr))
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, n_chips: int) -> Dict[str, float]:
    compute_t = flops / (n_chips * PEAK_FLOPS)
    memory_t = bytes_accessed / (n_chips * HBM_BW)
    coll_t = coll_bytes / (n_chips * LINK_BW)
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])
    return terms


def model_flops(n_params_active: float, n_tokens: float,
                train: bool) -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference."""
    per_tok = 6.0 if train else 2.0
    return per_tok * n_params_active * n_tokens
