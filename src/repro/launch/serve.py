"""Serving launcher — the paper's deployment shape.

Trains (or restores) the small DiT, then serves batched generation
requests through the FreqCa-cached DiffusionEngine and reports latency,
speedup vs the uncached engine, and output fidelity (PSNR vs uncached).

  PYTHONPATH=src python -m repro.launch.serve --requests 8 --interval 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_lib
from repro.core.cache import CachePolicy
from repro.launch.train import train_dit
from repro.models import common, dit
from repro.serving.engine import DiffusionEngine, DiffusionRequest


def psnr(a, b, data_range=2.0):
    mse = float(jnp.mean(jnp.square(a - b)))
    if mse == 0:
        return float("inf")
    return 10.0 * np.log10(data_range ** 2 / mse)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--interval", type=int, default=5)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--method", default="dct", choices=["dct", "fft"])
    args = ap.parse_args()

    cfg = config_lib.get_config("dit-small")
    print("training dit-small on synthetic shapes ...")
    params = train_dit(cfg, args.train_steps, 16, ckpt_dir="")
    size = 32
    n_tokens = (size // cfg.patch_size) ** 2

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, size, size)

    def engine(policy):
        return DiffusionEngine(full_fn, from_crf_fn,
                               (size, size, cfg.in_channels),
                               (n_tokens, cfg.d_model), policy,
                               n_steps=args.steps, max_batch=args.batch)

    eng_freqca = engine(CachePolicy(kind="freqca", interval=args.interval,
                                    method=args.method))
    eng_full = engine(CachePolicy(kind="none"))

    results = {}
    for name, eng in [("freqca", eng_freqca), ("full", eng_full)]:
        for i in range(args.requests):
            eng.submit(DiffusionRequest(request_id=i, seed=i))
        outs = []
        t0 = time.perf_counter()
        while True:
            batch_out = eng.run_batch()
            if not batch_out:
                break
            outs.extend(batch_out)
        wall = time.perf_counter() - t0
        results[name] = (outs, wall)
        print(f"[{name:7s}] served {len(outs)} requests in {wall:.2f}s "
              f"({wall / len(outs):.3f}s/req), "
              f"full steps/req: {outs[0].n_full_steps}/{args.steps}")

    f_outs, f_wall = results["freqca"]
    u_outs, u_wall = results["full"]
    ps = [psnr(f.latents, u.latents) for f, u in zip(f_outs, u_outs)]
    print(f"speedup {u_wall / f_wall:.2f}x  PSNR vs uncached: "
          f"{np.mean(ps):.2f} dB (min {np.min(ps):.2f})")


if __name__ == "__main__":
    main()
