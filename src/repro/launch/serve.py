"""Serving launcher — the paper's deployment shape, continuous batching.

Trains (or restores) the small DiT, precompiles one sampler executable
per batch bucket, then serves a mixed-size request stream (generation +
editing) through the FreqCa-cached DiffusionEngine.  Reports the
scheduler/engine metrics (occupancy, p50/p95 latency, full-step
fraction, compile cache), throughput, speedup vs the uncached engine,
and output fidelity (PSNR vs uncached).

Three client shapes:

* closed loop (``--arrival burst``, default) — deterministic bursts,
  each drained before the next arrives (the seed drivers' behaviour);
* open loop (``--arrival poisson --rate R``) — requests arrive on a
  Poisson process at R req/s regardless of server progress, so the
  queue builds while the engine is busy and the age/deadline batch
  former is exercised under real queueing.  The default replay is a
  single thread interleaving submits with engine turns (the sync
  baseline);
* threaded open loop (``--arrival poisson --clients N``) — the arrival
  plan is split over N real client threads submitting concurrently
  through ``AsyncDiffusionEngine``; every ``submit`` returns a future
  immediately and the engine's worker overlaps the clients.

``--mixed-policies`` assigns per-request cache policies (freqca / fora
/ freqca_a cycling).  By default the scheduler forms
**policy-homogeneous** batches (compatibility grouping): each cut is
pure, one warmed ladder per policy group covers every signature the
stream can produce (O(groups x buckets) executables instead of one per
round-robin window), and scheduled lanes never pay for adaptive lanes'
activations.  ``--ungrouped`` restores the mixed-lane batch former
(lanes in one batch follow their own activation schedules, one jit
signature per lane-policy mix — warmed via ``cyclic_signatures``).

``--replicas N`` (N > 1) serves the same stream through the
multi-process fleet instead: N replica processes each train-free (the
parent ships the trained params), warm their own bucket ladders, and
the ``FleetRouter`` places requests by policy-compatibility affinity +
load.  ``--replicas 1`` (the default) is the in-process path above,
bit-identical to before the flag existed.  The fleet is supervised:
``--max-restarts`` bounds per-slot restart attempts (dead replicas come
back with exponential backoff; crash-loopers are retired) and
``--max-inflight`` bounds per-replica queues (submit backpressures —
or sheds quality, with ``--shed-depth`` set — instead of queueing
without limit).

  PYTHONPATH=src python -m repro.launch.serve --requests 16 --interval 5
  PYTHONPATH=src python -m repro.launch.serve --arrival poisson --rate 2
  PYTHONPATH=src python -m repro.launch.serve --arrival poisson --rate 2 \
      --clients 4
  PYTHONPATH=src python -m repro.launch.serve --arrival poisson --rate 4 \
      --replicas 2
"""
from __future__ import annotations

import argparse
import functools
import itertools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_lib
from repro.core import policies as policy_lib
from repro.data import synthetic
from repro.launch.train import train_dit
from repro.models import dit
from repro.serving import metrics as metrics_lib
from repro.serving.async_engine import AsyncDiffusionEngine
from repro.serving.engine import DiffusionEngine, DiffusionRequest


def psnr(a, b, data_range=2.0):
    mse = float(jnp.mean(jnp.square(a - b)))
    if mse == 0:
        return float("inf")
    return 10.0 * np.log10(data_range ** 2 / mse)


def shape_ladder(cfg, sizes):
    """The (latent [H, W, C], CRF [S, D]) shape pair per image size:
    size ``s`` patchifies to ``(s / patch_size)^2`` tokens."""
    return [((s, s, cfg.in_channels),
             ((s // cfg.patch_size) ** 2, cfg.d_model)) for s in sizes]


def _make_request(rid: int, size: int, channels: int, edit_every: int,
                  policies=None, max_error=None,
                  shapes=None) -> DiffusionRequest:
    pol = policies[rid % len(policies)] if policies else None
    shape = shapes[rid % len(shapes)] if shapes else None
    lat = shape[0] if shape else None
    crf = shape[1] if shape else None
    if shape is not None:
        size = shape[0][0]    # edit refs must match the declared latent
    if edit_every and rid % edit_every == edit_every - 1:
        ref = synthetic.shapes_batch(jax.random.key(1000 + rid), 1,
                                     size=size, channels=channels)[0]
        return DiffusionRequest(request_id=rid, seed=rid, init_latents=ref,
                                edit_strength=0.5, policy=pol,
                                max_error=max_error,
                                latent_shape=lat, crf_shape=crf)
    return DiffusionRequest(request_id=rid, seed=rid, policy=pol,
                            max_error=max_error,
                            latent_shape=lat, crf_shape=crf)


def mixed_stream(n_requests: int, size: int, channels: int,
                 edit_every: int = 5, policies=None, max_error=None,
                 shapes=None):
    """Deterministic mixed request stream: bursts of varying size, every
    ``edit_every``-th request an editing request from a synthetic ref;
    optional per-request cache policies (and multi-resolution shape
    pairs) assigned round-robin."""
    reqs, rid = [], 0
    burst_sizes = itertools.cycle([1, 3, 8, 2, 4, 1])
    while rid < n_requests:
        burst = []
        for _ in range(min(next(burst_sizes), n_requests - rid)):
            burst.append(_make_request(rid, size, channels, edit_every,
                                       policies, max_error=max_error,
                                       shapes=shapes))
            rid += 1
        reqs.append(burst)
    return reqs


def poisson_stream(n_requests: int, rate: float, size: int, channels: int,
                   edit_every: int = 5, policies=None, seed: int = 0,
                   max_error=None, shapes=None):
    """Open-loop arrival plan: a flat list of ``DiffusionRequest`` with
    exponential inter-arrival times at ``rate`` req/s stamped into each
    request's ``arrival_s`` (deterministic for a given ``seed``) — the
    unified request object carries its own arrival, no side-channel
    tuples.  ``shapes`` cycles multi-resolution shape pairs round-robin
    so a mixed 256/512/1024-token stream is one flag away."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.RandomState(seed)
    t, plan = 0.0, []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        req = _make_request(rid, size, channels, edit_every, policies,
                            max_error=max_error, shapes=shapes)
        req.arrival_s = t
        plan.append(req)
    return plan


def serve_stream(eng: DiffusionEngine, bursts) -> tuple:
    """Replay bursts through the engine; each burst is drained before the
    next arrives (closed-loop client)."""
    outs = []
    t0 = time.perf_counter()
    for burst in bursts:
        for r in burst:
            eng.submit(r)
        outs.extend(eng.serve_until_drained())
    wall = time.perf_counter() - t0
    return outs, wall


def cyclic_signatures(policies, max_batch: int):
    """Every per-lane policy set an UNGROUPED FIFO batch former can cut
    from a round-robin assignment: windows of the policy cycle (any
    offset, any real-lane count), padded to their bucket with the
    window's first policy — the engine's padding rule.  Warming these
    makes ungrouped open-loop serving compile-free no matter where
    arrivals split the batches; it is also the O(mixes x buckets)
    signature blowup the policy-homogeneous former avoids (grouped,
    ``warmup(policies=...)`` — one uniform ladder per group — covers
    the same stream)."""
    from repro.serving.scheduler import bucket_for
    seen, sets = set(), []
    k = len(policies)
    for off in range(k):
        for n in range(1, max_batch + 1):
            lanes = [policies[(off + i) % k] for i in range(n)]
            lanes += [lanes[0]] * (bucket_for(n, max_batch) - n)
            key = tuple(lanes)
            if key not in seen:
                seen.add(key)
                sets.append(key)
    return sets


def serve_open_loop(eng: DiffusionEngine, plan, poll_s: float = 0.002):
    """Replay a timestamped arrival plan in real time (open-loop client).

    Arrivals are independent of server progress: the queue grows while
    the engine is busy, so batches are cut by the scheduler's own
    age/deadline pressure (``flush=False``) rather than drained — the
    regime the closed-loop drivers never reach.
    """
    outs, i = [], 0
    t0 = time.perf_counter()
    while i < len(plan) or eng.scheduler.depth:
        now = time.perf_counter() - t0
        while i < len(plan) and plan[i].arrival_s <= now:
            eng.submit(plan[i], now=plan[i].arrival_s)
            i += 1
        served = eng.run_batch(flush=False, now=now)
        outs.extend(served)
        if not served:   # nothing ready: wait for arrivals/age, don't spin
            time.sleep(poll_s)
    return outs, time.perf_counter() - t0


def serve_threaded_open_loop(eng: DiffusionEngine, plan, clients: int = 4):
    """Replay a timestamped arrival plan from N concurrent client threads.

    The plan is split round-robin over ``clients`` threads; each thread
    sleeps until its requests' arrival times and submits through the
    thread-safe ``AsyncDiffusionEngine`` — every submit returns a future
    immediately, so clients never block on the engine and the worker
    overlaps them (the regime the single-thread replay can't reach:
    there, a slow batch delays every later arrival's submission).
    Returns ``(results_in_request_order, wall_s)``.
    """
    if clients < 1:
        raise ValueError(f"clients must be >= 1, got {clients}")
    futures = [None] * len(plan)
    with AsyncDiffusionEngine(eng) as aeng:
        t0 = time.perf_counter()

        def client(k: int):
            for i in range(k, len(plan), clients):
                req = plan[i]
                delay = req.arrival_s - (time.perf_counter() - t0)
                if delay > 0:
                    time.sleep(delay)
                futures[i] = aeng.submit(req)

        threads = [threading.Thread(target=client, args=(k,), daemon=True)
                   for k in range(clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        # all clients are done submitting: flush the tail batch instead
        # of letting it age out (the sync replay can't know this)
        aeng.drain()
        outs = [f.result() for f in futures]   # stream back as they land
        wall = time.perf_counter() - t0
    return outs, wall


def _default_policy(args):
    """The stream's default cache policy from the CLI flags (shared by
    the in-process and fleet paths so the two serve identical streams)."""
    if args.max_error is not None:
        # quality-SLO serving: the error-budgeted policy spends each
        # request's max_error between full forwards
        return policy_lib.FreqCaErrorBudgetPolicy(
            method=args.method, rho=0.25).with_budget(args.max_error)
    return policy_lib.FreqCaPolicy(interval=args.interval,
                                   method=args.method)


def _stream_policies(args, default_pol):
    """Per-request policy cycle for ``--mixed-policies`` (else None)."""
    if not args.mixed_policies:
        return None
    return [default_pol,
            policy_lib.ForaPolicy(interval=args.interval),
            policy_lib.FreqCaAdaptivePolicy(method=args.method,
                                            rho=0.25, tea_threshold=0.3)]


def fleet_engine_factory(params_np, cfg_name: str, size: int, steps: int,
                         batch: int, max_wait: float, method: str,
                         interval: int, max_error, grouped: bool,
                         shed_depth, shed_factor: float, sizes=None):
    """Zero-arg-able engine builder for fleet workers.

    Module-level (so ``functools.partial`` of it pickles under the
    spawn start method) and takes params as a *numpy* pytree — the
    child converts to device arrays after its own jax init, so the
    parent's device state never crosses the process boundary.
    ``sizes`` declares a multi-resolution shape ladder (image sizes;
    ``size`` stays the primary) — every replica then warms and serves
    the full ladder.
    """
    cfg = config_lib.get_config(cfg_name)
    params = jax.tree_util.tree_map(jnp.asarray, params_np)
    n_tokens = (size // cfg.patch_size) ** 2

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        # shape-generic decode: the image side is recovered from the
        # token count, so one callable serves the whole shape ladder
        tb = jnp.full((crf.shape[0],), t)
        side = int(round(crf.shape[1] ** 0.5)) * cfg.patch_size
        return dit.dit_from_crf(params, crf, tb, cfg, side, side)

    if max_error is not None:
        pol = policy_lib.FreqCaErrorBudgetPolicy(
            method=method, rho=0.25).with_budget(max_error)
    else:
        pol = policy_lib.FreqCaPolicy(interval=interval, method=method)
    return DiffusionEngine(full_fn, from_crf_fn,
                           (size, size, cfg.in_channels),
                           (n_tokens, cfg.d_model), pol,
                           n_steps=steps, max_batch=batch,
                           max_wait_s=max_wait, group_policies=grouped,
                           shed_depth=shed_depth, shed_factor=shed_factor,
                           shapes=shape_ladder(cfg, sizes or ()))


def serve_fleet_open_loop(router, plan, clients: int = 4):
    """Replay a timestamped arrival plan through a ``FleetRouter`` from
    N concurrent client threads — the fleet twin of
    ``serve_threaded_open_loop`` (same submit-at-arrival contract, the
    router's drain flushes the tail on every replica)."""
    if clients < 1:
        raise ValueError(f"clients must be >= 1, got {clients}")
    futures = [None] * len(plan)
    t0 = time.perf_counter()

    def client(k: int):
        for i in range(k, len(plan), clients):
            req = plan[i]
            delay = req.arrival_s - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            futures[i] = router.submit(req)

    threads = [threading.Thread(target=client, args=(k,), daemon=True)
               for k in range(clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    router.drain()
    outs = [f.result() for f in futures]
    wall = time.perf_counter() - t0
    return outs, wall


def _parse_sizes(args, primary: int):
    """The image-size ladder from ``--sizes`` (primary first, deduped)."""
    sizes = [primary]
    for tok in (getattr(args, "sizes", "") or "").split(","):
        tok = tok.strip()
        if tok and int(tok) not in sizes:
            sizes.append(int(tok))
    return sizes


def serve_fleet_main(args, params, size: int, channels: int):
    """The ``--replicas N`` (N > 1) serving path: ship the trained
    params to N worker processes, route the stream through the fleet
    frontend, report fleet-wide + per-replica + routing metrics."""
    from repro.serving.fleet import FleetRouter
    default_pol = _default_policy(args)
    pols = _stream_policies(args, default_pol)
    extra = list(pols) if pols else []
    if args.max_error is not None and args.shed_depth is not None:
        extra.append(default_pol.with_budget(
            args.max_error * args.shed_factor))
    cfg = config_lib.get_config("dit-small")
    sizes = _parse_sizes(args, size)
    shapes = shape_ladder(cfg, sizes) if len(sizes) > 1 else None
    params_np = jax.tree_util.tree_map(np.asarray, params)
    factory = functools.partial(
        fleet_engine_factory, params_np, "dit-small", size, args.steps,
        args.batch, args.max_wait, args.method, args.interval,
        args.max_error, not args.ungrouped, args.shed_depth,
        args.shed_factor, sizes=sizes if len(sizes) > 1 else None)
    if args.arrival == "poisson":
        plan = poisson_stream(args.requests, args.rate, size, channels,
                              edit_every=args.edit_every, policies=pols,
                              max_error=args.max_error, shapes=shapes)
    else:
        plan = [r for burst in mixed_stream(
            args.requests, size, channels, edit_every=args.edit_every,
            policies=pols, max_error=args.max_error,
            shapes=shapes) for r in burst]
        for r in plan:
            r.arrival_s = 0.0
    router = FleetRouter(factory, n_replicas=args.replicas,
                         warm={"policies": extra},
                         default_policy=default_pol,
                         max_restarts=args.max_restarts,
                         max_inflight=args.max_inflight,
                         shed_factor=(args.shed_factor
                                      if args.shed_depth is not None
                                      else None))
    print(f"booting {args.replicas} replicas (spawn + warmup) ...")
    router.start()
    for r in router.replicas:
        print(f"[replica {r.idx}] pid {r.meta['pid']} warmed "
              f"{r.meta['warmup_compiles']} executables in "
              f"{r.meta['warmup_s']:.1f}s")
    try:
        outs, wall = serve_fleet_open_loop(
            router, plan, clients=max(args.clients, 1))
        fm = router.fleet_metrics()
    finally:
        router.shutdown(drain=True)
    s = fm.summary()
    fleet, routing = s["fleet"], s["routing"]
    rps = len(outs) / wall if wall > 0 else float("nan")
    print(f"[fleet  ] served {len(outs)} requests in {wall:.2f}s "
          f"({rps:.2f} req/s) across {fleet['replicas']} replicas")
    print(f"[fleet  ] occupancy {fleet['mean_occupancy']:.2f}  "
          f"latency p50/p95 {fleet['request_latency_p50_s']:.3f}/"
          f"{fleet['request_latency_p95_s']:.3f}s  "
          f"skip-compute {fleet['skip_compute_fraction']:.2f}")
    print(f"[fleet  ] routing: {routing['affinity_hits']} affinity, "
          f"{routing['new_groups']} new groups, {routing['spills']} "
          f"spills, {routing['requeued']} requeued, "
          f"{routing['replicas_lost']} replicas lost")
    if args.max_restarts > 0:
        print(f"[fleet  ] supervision: {routing.get('restarts', 0)} "
              f"restarts, {routing.get('boot_failures', 0)} boot "
              f"failures, {routing.get('replicas_retired', 0)} retired, "
              f"backoff {routing.get('restart_backoff_s', 0.0):.2f}s; "
              f"{routing['stale_pong_kills']} stale-pong kills, "
              f"{routing['poison_quarantined']} quarantined, "
              f"{routing['backpressure_waits']} backpressured "
              f"(peak inflight {routing['peak_inflight']})")
    for idx, pr in s["per_replica"].items():
        print(f"[replica {idx}] {pr['requests']} reqs / "
              f"{pr['batches']} batches, occupancy "
              f"{pr['mean_occupancy']:.2f}, steady recompiles "
              f"{pr['steady_recompiles']}")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--interval", type=int, default=5)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch (largest bucket signature)")
    ap.add_argument("--method", default="dct", choices=["dct", "fft"])
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="age threshold for batch formation (s)")
    ap.add_argument("--edit-every", type=int, default=5,
                    help="every Nth request is an editing request (0=off)")
    ap.add_argument("--arrival", default="burst",
                    choices=["burst", "poisson"],
                    help="closed-loop bursts or open-loop Poisson client")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate (req/s) for --arrival poisson")
    ap.add_argument("--clients", type=int, default=0,
                    help="N concurrent client threads through the async "
                         "engine for --arrival poisson (0 = single-thread "
                         "sync replay baseline)")
    ap.add_argument("--mixed-policies", action="store_true",
                    help="cycle per-request policies (freqca/fora/freqca_a)"
                         " — lanes in one batch keep their own schedules")
    ap.add_argument("--ungrouped", action="store_true",
                    help="disable policy-homogeneous batch formation "
                         "(mixed-lane batches, one jit signature per "
                         "lane-policy mix — the pre-grouping baseline)")
    ap.add_argument("--max-error", type=float, default=None,
                    help="per-request quality SLO: serve through the "
                         "error-budgeted freqca_eb policy, bounding the "
                         "cache error accumulated between full forwards")
    ap.add_argument("--shed-depth", type=int, default=None,
                    help="queue depth at which incoming requests' error "
                         "budgets are relaxed by --shed-factor (load "
                         "shedding: quality, never requests)")
    ap.add_argument("--shed-factor", type=float, default=4.0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replica processes behind the fleet "
                         "router; 1 (default) = the in-process engine "
                         "path, unchanged")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="restart attempts per replica slot before it is "
                         "permanently retired (fleet supervision; 0 "
                         "disables restarts — the PR-7 shrink-only fleet)")
    ap.add_argument("--max-inflight", type=int, default=0,
                    help="outstanding requests per replica before "
                         "submit() backpressures (0 = unbounded)")
    ap.add_argument("--sizes", default="",
                    help="comma-separated extra image sizes to serve "
                         "alongside the primary (multi-resolution shape "
                         "ladder, e.g. --sizes 16,64: requests cycle "
                         "sizes round-robin, every cut is shape-pure, "
                         "executables stay <= shapes x groups x buckets)")
    return ap


def main():
    args = build_parser().parse_args()

    if args.requests < 1:
        raise SystemExit("--requests must be >= 1")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    cfg = config_lib.get_config("dit-small")
    print("training dit-small on synthetic shapes ...")
    params = train_dit(cfg, args.train_steps, 16, ckpt_dir="")
    size = 32
    if args.replicas > 1:
        serve_fleet_main(args, params, size, cfg.in_channels)
        return
    n_tokens = (size // cfg.patch_size) ** 2
    sizes = _parse_sizes(args, size)
    shapes = shape_ladder(cfg, sizes) if len(sizes) > 1 else None

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        # shape-generic: recover the image side from the token count so
        # one callable decodes every ladder entry
        tb = jnp.full((crf.shape[0],), t)
        side = int(round(crf.shape[1] ** 0.5)) * cfg.patch_size
        return dit.dit_from_crf(params, crf, tb, cfg, side, side)

    def engine(policy):
        return DiffusionEngine(full_fn, from_crf_fn,
                               (size, size, cfg.in_channels),
                               (n_tokens, cfg.d_model), policy,
                               n_steps=args.steps, max_batch=args.batch,
                               max_wait_s=args.max_wait,
                               group_policies=not args.ungrouped,
                               shed_depth=args.shed_depth,
                               shed_factor=args.shed_factor,
                               shapes=shapes or ())

    default_pol = _default_policy(args)
    policies = _stream_policies(args, default_pol)
    eng_freqca = engine(default_pol)
    eng_full = engine(policy_lib.NoCachePolicy())

    results = {}
    for name, eng in [("freqca", eng_freqca), ("full", eng_full)]:
        pols = policies if name == "freqca" else None
        # mixed-policy batches add (bucket, lane-policy) signatures the
        # default ladder doesn't cover.  Grouped (the default), a
        # policy-pure former only ever cuts uniform signatures: one
        # ladder per compatibility group covers the whole stream.
        # Ungrouped, every round-robin window the FIFO former can cut
        # is its own mix — warm them all via cyclic_signatures.
        sets = cyclic_signatures(pols, args.batch) \
            if pols and args.ungrouped else ()
        extra = list(pols) if pols and not args.ungrouped else []
        if args.max_error is not None and args.shed_depth is not None:
            # shedding mints the relaxed-tier signature: warm it too so
            # overload serving stays compile-free
            extra.append(default_pol.with_budget(
                args.max_error * args.shed_factor))
        warm = eng.warmup(lane_policy_sets=sets, policies=extra)
        n_exec = eng.compiled_buckets()
        print(f"[{name:7s}] warmup: {n_exec} executables "
              f"({len(eng.buckets)} buckets x "
              f"{'policy groups' if not args.ungrouped else 'policy mixes'}"
              f") in {warm:.1f}s")
        max_err = args.max_error if name == "freqca" else None
        if args.arrival == "poisson":
            plan = poisson_stream(args.requests, args.rate, size,
                                  cfg.in_channels,
                                  edit_every=args.edit_every, policies=pols,
                                  max_error=max_err, shapes=shapes)
            if args.clients > 0:
                outs, wall = serve_threaded_open_loop(eng, plan,
                                                      clients=args.clients)
            else:
                outs, wall = serve_open_loop(eng, plan)
        else:
            bursts = mixed_stream(args.requests, size, cfg.in_channels,
                                  edit_every=args.edit_every, policies=pols,
                                  max_error=max_err, shapes=shapes)
            outs, wall = serve_stream(eng, bursts)
        outs.sort(key=lambda o: o.request_id)
        results[name] = (outs, wall)
        s = eng.metrics.summary()
        rps = metrics_lib.throughput(eng.metrics, wall)
        fulls = sorted(o.n_full_steps for o in outs)
        print(f"[{name:7s}] served {len(outs)} requests in {wall:.2f}s "
              f"({rps:.2f} req/s), full steps/req: "
              f"{fulls[0]}..{fulls[-1]}/{args.steps}")
        ttfr = s["time_to_first_result_s"]
        print(f"[{name:7s}] occupancy {s['mean_occupancy']:.2f}  "
              f"latency p50/p95 {s['request_latency_p50_s']:.3f}/"
              f"{s['request_latency_p95_s']:.3f}s  "
              f"skip-compute {s['skip_compute_fraction']:.2f}  "
              f"lane spread {s['max_lane_full_spread']}  "
              f"compiles {s['compile_misses']} "
              f"(steady-state hits {s['compile_hits']}, "
              f"signatures {s['compiled_signatures']})"
              + (f"  ttfr {ttfr:.3f}s" if ttfr is not None else ""))
        if args.max_error is not None and name == "freqca":
            print(f"[{name:7s}] quality SLO: realized error p50/p95 "
                  f"{s['realized_error_p50']:.4f}/"
                  f"{s['realized_error_p95']:.4f} "
                  f"(budget {args.max_error}), "
                  f"budget events {s['budget_events']}, "
                  f"shed events {s['shed_events']}")
        if s["policy_groups"]:
            for key, g in s["per_group"].items():
                print(f"          group {key}: {g['requests']} reqs in "
                      f"{g['batches']} batches, occupancy "
                      f"{g['mean_occupancy']:.2f}"
                      + (f", budget events {g['budget_events']}"
                         if g["budget_events"] else ""))
        if s.get("shape_keys", 0) > 1:
            for key, sh in s["per_shape"].items():
                print(f"          shape {key}: {sh['requests']} reqs in "
                      f"{sh['batches']} batches, occupancy "
                      f"{sh['mean_occupancy']:.2f}")

    f_outs, f_wall = results["freqca"]
    u_outs, u_wall = results["full"]
    ps = [psnr(f.latents, u.latents)
          for f, u in zip(f_outs, u_outs, strict=True)]
    print(f"speedup {u_wall / f_wall:.2f}x  PSNR vs uncached: "
          f"{np.mean(ps):.2f} dB (min {np.min(ps):.2f})")


if __name__ == "__main__":
    main()
