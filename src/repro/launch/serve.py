"""Serving launcher — the paper's deployment shape, continuous batching.

Trains (or restores) the small DiT, precompiles one sampler executable
per batch bucket, then serves a mixed-size request stream (generation +
editing) through the FreqCa-cached DiffusionEngine.  Reports the
scheduler/engine metrics (occupancy, p50/p95 latency, full-step
fraction, compile cache), throughput, speedup vs the uncached engine,
and output fidelity (PSNR vs uncached).

  PYTHONPATH=src python -m repro.launch.serve --requests 16 --interval 5
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_lib
from repro.core.cache import CachePolicy
from repro.data import synthetic
from repro.launch.train import train_dit
from repro.models import dit
from repro.serving import metrics as metrics_lib
from repro.serving.engine import DiffusionEngine, DiffusionRequest


def psnr(a, b, data_range=2.0):
    mse = float(jnp.mean(jnp.square(a - b)))
    if mse == 0:
        return float("inf")
    return 10.0 * np.log10(data_range ** 2 / mse)


def mixed_stream(n_requests: int, size: int, channels: int,
                 edit_every: int = 5):
    """Deterministic mixed request stream: bursts of varying size, every
    ``edit_every``-th request an editing request from a synthetic ref."""
    reqs, rid = [], 0
    burst_sizes = itertools.cycle([1, 3, 8, 2, 4, 1])
    while rid < n_requests:
        burst = []
        for _ in range(min(next(burst_sizes), n_requests - rid)):
            if edit_every and rid % edit_every == edit_every - 1:
                ref = synthetic.shapes_batch(jax.random.key(1000 + rid), 1,
                                             size=size, channels=channels)[0]
                burst.append(DiffusionRequest(request_id=rid, seed=rid,
                                              init_latents=ref,
                                              edit_strength=0.5))
            else:
                burst.append(DiffusionRequest(request_id=rid, seed=rid))
            rid += 1
        reqs.append(burst)
    return reqs


def serve_stream(eng: DiffusionEngine, bursts) -> tuple:
    """Replay bursts through the engine; each burst is drained before the
    next arrives (closed-loop client)."""
    outs = []
    t0 = time.perf_counter()
    for burst in bursts:
        for r in burst:
            eng.submit(r)
        outs.extend(eng.serve_until_drained())
    wall = time.perf_counter() - t0
    return outs, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--interval", type=int, default=5)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch (largest bucket signature)")
    ap.add_argument("--method", default="dct", choices=["dct", "fft"])
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="age threshold for batch formation (s)")
    ap.add_argument("--edit-every", type=int, default=5,
                    help="every Nth request is an editing request (0=off)")
    args = ap.parse_args()

    if args.requests < 1:
        raise SystemExit("--requests must be >= 1")
    cfg = config_lib.get_config("dit-small")
    print("training dit-small on synthetic shapes ...")
    params = train_dit(cfg, args.train_steps, 16, ckpt_dir="")
    size = 32
    n_tokens = (size // cfg.patch_size) ** 2

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, size, size)

    def engine(policy):
        return DiffusionEngine(full_fn, from_crf_fn,
                               (size, size, cfg.in_channels),
                               (n_tokens, cfg.d_model), policy,
                               n_steps=args.steps, max_batch=args.batch,
                               max_wait_s=args.max_wait)

    eng_freqca = engine(CachePolicy(kind="freqca", interval=args.interval,
                                    method=args.method))
    eng_full = engine(CachePolicy(kind="none"))

    results = {}
    for name, eng in [("freqca", eng_freqca), ("full", eng_full)]:
        warm = eng.warmup()
        print(f"[{name:7s}] warmup: {len(eng.buckets)} bucket executables "
              f"in {warm:.1f}s")
        bursts = mixed_stream(args.requests, size, cfg.in_channels,
                              edit_every=args.edit_every)
        outs, wall = serve_stream(eng, bursts)
        outs.sort(key=lambda o: o.request_id)
        results[name] = (outs, wall)
        s = eng.metrics.summary()
        rps = metrics_lib.throughput(eng.metrics, wall)
        print(f"[{name:7s}] served {len(outs)} requests in {wall:.2f}s "
              f"({rps:.2f} req/s), full steps/req: "
              f"{outs[0].n_full_steps}/{args.steps}")
        print(f"[{name:7s}] occupancy {s['mean_occupancy']:.2f}  "
              f"latency p50/p95 {s['request_latency_p50_s']:.3f}/"
              f"{s['request_latency_p95_s']:.3f}s  "
              f"full-step frac {s['full_step_fraction']:.2f}  "
              f"compiles {s['compile_misses']} "
              f"(steady-state hits {s['compile_hits']})")

    f_outs, f_wall = results["freqca"]
    u_outs, u_wall = results["full"]
    ps = [psnr(f.latents, u.latents) for f, u in zip(f_outs, u_outs)]
    print(f"speedup {u_wall / f_wall:.2f}x  PSNR vs uncached: "
          f"{np.mean(ps):.2f} dB (min {np.min(ps):.2f})")


if __name__ == "__main__":
    main()
