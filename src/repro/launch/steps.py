"""Step builders + abstract input specs for every (arch x input-shape).

``build(arch_id, shape_name, mesh)`` returns a ``StepSpec`` bundling the
step function, abstract (ShapeDtypeStruct) arguments — weak-type-correct
and shardable, no device allocation — and in/out shardings.  This is the
single entry point used by the dry-run, the roofline analysis, and the
integration tests (which call it on a small host-device mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as config_lib
from repro.configs.base import DiTConfig, ModelConfig
from repro.models import blocks, common, dit, encdec, transformer
from repro.optim import adamw
from repro.sharding import partitioning as pt


@dataclasses.dataclass
class StepSpec:
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()


def _abstract_opt_state(params_abs):
    zeros_like = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return adamw.OptState(
        mu=jax.tree.map(zeros_like, params_abs),
        nu=jax.tree.map(zeros_like, params_abs),
        step=jax.ShapeDtypeStruct((), jnp.int32))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def model_specs(cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.encdec_specs(cfg)
    return transformer.lm_specs(cfg)


def _cache_shardings(cfg: ModelConfig, mesh: Mesh, rules, global_batch: int):
    dp = pt.dp_axes(mesh)
    dpsz = pt._axis_size(mesh, dp)
    cache_rules = dict(rules)
    cache_rules["layer"] = None
    if global_batch % dpsz == 0 and global_batch >= dpsz:
        cache_rules["batch"] = dp
        cache_rules["len"] = None
    else:
        # single-request long-context: shard the KV length instead
        cache_rules["batch"] = None
        cache_rules["len"] = "data"
    axes = blocks.stack_cache_axes(cfg)
    return jax.tree.map(
        lambda a: NamedSharding(mesh, pt.spec_for_axes(a, cache_rules)),
        axes, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def activation_constrain(mesh: Optional[Mesh], mode: str = "serve",
                         seq_len: int = 0):
    """Pin [B, S, D] activations between blocks.

    serve: batch on dp only.  train: additionally shard the SEQUENCE dim
    on "model" (Megatron sequence parallelism) — the layer-scan carry is
    what remat stores per layer, and for a 126-layer 405B config an
    unsharded d_model carry alone is ~270 GB/device.  GSPMD turns the
    constraint into the standard SP all-gather before attention/FFN and
    reduce-scatter after.
    """
    if mesh is None:
        return None
    seq_entry = None
    if mode == "train" and seq_len and seq_len % mesh.shape["model"] == 0:
        seq_entry = "model"
    spec = P(pt.dp_axes(mesh), seq_entry, None)

    def constrain(t):
        if t.ndim == 3:
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, spec))
        return t
    return constrain


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[adamw.AdamWConfig]
                    = None, mesh: Optional[Mesh] = None, seq_len: int = 0,
                    microbatch: int = 1):
    """``microbatch > 1`` = gradient accumulation: the global batch is
    split into ``microbatch`` sequential sub-batches inside one jitted
    step (lax.scan), dividing peak activation memory by the same factor
    at unchanged math (§Perf memory iteration for the >=100B trains)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        moment_dtype="bfloat16" if pt.param_bytes(cfg) > 2e11 else "float32")
    loss = encdec.loss_fn if cfg.is_encdec else transformer.loss_fn
    constrain = activation_constrain(mesh, "train", seq_len)
    constrain_ffn = None
    if mesh is not None and cfg.d_ff % mesh.shape["model"] == 0:
        ffn_spec = P(pt.dp_axes(mesh), None, "model")

        def constrain_ffn(t):  # noqa: F811 — Megatron-SP TP switch
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, ffn_spec))

    # REFUTED (§Perf A5): pinning q to a head-sharded layout the same way
    # regressed collectives 17->39 TB/dev on llama3-405b train — GSPMD
    # inserts an S->H reshard before RoPE and back inside every layer;
    # the FFN hook alone is the right Megatron-SP boundary.
    constrain_heads = None

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss(p, batch, cfg, constrain=constrain,
                           constrain_ffn=constrain_ffn,
                           constrain_heads=constrain_heads),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)

            def body(acc, one):
                (l, metrics), grads = grads_of(params, one)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatch,
                    acc, grads)
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(body, zeros, mb)
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            (l, metrics), grads = grads_of(params, batch)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_opt, metrics

    return train_step, opt_cfg


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      seq_len: int = 0):
    """Prefill: full-sequence forward, last-token logits only (the
    [B, S, vocab] tensor must never materialise at 32k).  Sequence
    parallel like train — prefill is the same forward."""
    constrain = activation_constrain(mesh, "train", seq_len) or (
        lambda t: t)

    def prefill_step(params, batch):
        if cfg.is_encdec:
            memory = encdec.encode(params, batch["frames"], cfg,
                                   constrain=constrain)
            x = common.embed(params["embed"],
                             batch["tokens"]).astype(jnp.dtype(cfg.dtype))

            def body(h, layer_params):
                h, _ = encdec._dec_block(layer_params, h, memory, cfg)
                return constrain(h), ()
            h, _ = jax.lax.scan(body, constrain(x), params["decoder"])
            hn = common.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
            return (hn @ params["head"]["kernel"].astype(hn.dtype))[:, 0]
        x = common.embed(params["embed"],
                         batch["tokens"]).astype(jnp.dtype(cfg.dtype))
        if cfg.n_prefix_tokens > 0:
            pe = common.dense(params["prefix_proj"],
                              batch["prefix_embeds"].astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        h, _ = blocks.stack_full(params["stack"], x, cfg, remat=False,
                                 constrain=constrain)
        hn = common.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
        w = transformer._embedding_matrix(params, cfg)
        return (hn @ w.astype(hn.dtype))[:, 0]
    return prefill_step


def make_decode_step(cfg: ModelConfig, window: int = 0):
    if cfg.is_encdec:
        def decode_step(params, tokens, cache, memory):
            logits, new_cache = encdec.decode_step(params, tokens, memory,
                                                   cache, cfg, window=window)
            return logits, new_cache
        return decode_step

    def decode_step(params, tokens, cache):
        logits, new_cache = transformer.decode_step(params, tokens, cache,
                                                    cfg, window=window)
        return logits, new_cache
    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Abstract model inputs for a named input shape (no allocation)."""
    info = config_lib.INPUT_SHAPES[shape_name]
    seq, gb, kind = info["seq_len"], info["global_batch"], info["kind"]
    dtype = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if kind in ("train", "prefill"):
        if cfg.is_encdec:
            batch = {
                "frames": jax.ShapeDtypeStruct((gb, seq, cfg.d_model), dtype),
                "tokens": jax.ShapeDtypeStruct((gb, seq), i32),
            }
        elif cfg.n_prefix_tokens > 0:
            text = seq - cfg.n_prefix_tokens
            batch = {
                "prefix_embeds": jax.ShapeDtypeStruct(
                    (gb, cfg.n_prefix_tokens, cfg.d_model), dtype),
                "tokens": jax.ShapeDtypeStruct((gb, text), i32),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((gb, seq), i32)}
        if kind == "train":
            lab = batch["tokens"].shape[1] if not cfg.is_encdec else seq
            batch["labels"] = jax.ShapeDtypeStruct((gb, lab), i32)
        return batch

    assert kind == "decode"
    out = {"tokens": jax.ShapeDtypeStruct((gb, 1), i32),
           "cache": blocks.stack_cache_abstract(cfg, gb, seq, dtype)}
    if cfg.is_encdec:
        out["memory"] = jax.ShapeDtypeStruct((gb, seq, cfg.d_model), dtype)
    return out


def _batch_shardings(batch_abs, mesh: Mesh, gb: int):
    def one(x):
        return pt.batch_spec(mesh, gb, len(x.shape))
    return jax.tree.map(one, batch_abs)


def build(arch_id: str, shape_name: str, mesh: Mesh,
          overrides: Optional[Dict[str, Any]] = None) -> StepSpec:
    """Assemble (fn, abstract args, shardings) for one dry-run combo.

    ``overrides`` (perf iterations): microbatch=int, moe_impl=str,
    serve_tp_gb=float.
    """
    ov = overrides or {}
    base_cfg = config_lib.get_config(arch_id)
    assert isinstance(base_cfg, ModelConfig), \
        f"{arch_id} is a DiT config; use build_dit()"
    cfg = config_lib.for_shape(base_cfg, shape_name)
    if cfg.moe is not None and (ov.get("moe_impl") or ov.get("moe_pad")):
        moe_kw = {}
        if ov.get("moe_impl"):
            moe_kw["impl"] = ov["moe_impl"]
        if ov.get("moe_pad"):
            moe_kw["padded_experts"] = int(ov["moe_pad"])
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_kw))
    info = config_lib.INPUT_SHAPES[shape_name]
    gb, kind = info["global_batch"], info["kind"]
    mode = "train" if kind == "train" else "serve"
    rules = pt.model_rules(cfg, mesh, mode,
                           serve_tp_bytes=float(
                               ov.get("serve_tp_gb", 4.0)) * 1e9,
                           shape_kind=kind)

    specs = model_specs(cfg)
    params_abs = common.abstract_params(specs, jnp.dtype(cfg.dtype))
    params_sh = pt.shardings_for_specs(specs, rules, mesh)

    if kind == "train":
        fn, opt_cfg = make_train_step(cfg, mesh=mesh,
                                      seq_len=info["seq_len"],
                                      microbatch=int(ov.get("microbatch",
                                                            1)))
        batch_abs = input_specs(cfg, shape_name)
        opt_abs = adamw.OptState(
            mu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(opt_cfg.moment_dtype)), params_abs),
            nu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(opt_cfg.moment_dtype)), params_abs),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        opt_sh = adamw.OptState(mu=params_sh, nu=params_sh,
                                step=_replicated(mesh))
        batch_sh = _batch_shardings(batch_abs, mesh, gb)
        metrics_sh = _replicated(mesh)
        return StepSpec(
            name=f"{arch_id}:{shape_name}:train",
            fn=fn, args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1))

    if kind == "prefill":
        fn = make_prefill_step(cfg, mesh=mesh, seq_len=info["seq_len"])
        batch_abs = input_specs(cfg, shape_name)
        batch_sh = _batch_shardings(batch_abs, mesh, gb)
        return StepSpec(
            name=f"{arch_id}:{shape_name}:prefill",
            fn=fn, args=(params_abs, batch_abs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None)

    # decode
    window = cfg.sliding_window
    seq = info["seq_len"]
    cache_len = min(seq, window) if window > 0 else seq
    fn = make_decode_step(cfg, window=window)
    ins = input_specs(cfg, shape_name)
    if cfg.is_encdec:
        cache_abs = encdec.decode_cache_abstract(cfg, gb, cache_len,
                                                 jnp.dtype(cfg.dtype))
        dp = pt.dp_axes(mesh)
        dpsz = pt._axis_size(mesh, dp)
        cache_rules = dict(rules)
        if gb % dpsz == 0 and gb >= dpsz:
            cache_rules.update({"layer": None, "batch": dp, "len": None})
        else:
            cache_rules.update({"layer": None, "batch": None,
                                "len": "data"})
        axes = blocks.attention.KVCache(
            k=("layer", "batch", "len", "kv_heads", "kv_head_dim"),
            v=("layer", "batch", "len", "kv_heads", "kv_head_dim"),
            index=("layer",))
        cache_sh = jax.tree.map(
            lambda a: NamedSharding(mesh, pt.spec_for_axes(a, cache_rules)),
            axes, is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(e, (str, type(None))) for e in x))
    else:
        cache_abs = blocks.stack_cache_abstract(cfg, gb, cache_len,
                                                jnp.dtype(cfg.dtype))
        cache_sh = _cache_shardings(cfg, mesh, rules, gb)
    tok_sh = pt.batch_spec(mesh, gb, 2)
    args = [params_abs, ins["tokens"], cache_abs]
    in_sh = [params_sh, tok_sh, cache_sh]
    if cfg.is_encdec:
        args.append(ins["memory"])
        in_sh.append(pt.batch_spec(mesh, gb, 3))
    return StepSpec(
        name=f"{arch_id}:{shape_name}:decode",
        fn=fn, args=tuple(args), in_shardings=tuple(in_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,))


def build_dit(arch_id: str, mesh: Mesh, batch: int = 64,
              latent: int = 128, cached_step: bool = False) -> StepSpec:
    """Dry-run spec for the paper's own MMDiT.

    ``cached_step=False``: one full denoiser forward (the activated
    step).  ``cached_step=True``: the FreqCa skip path — band
    reconstruction from the cache + the final layer only — so the
    roofline of the step the paper makes ~N-1 of every N can be compared
    against the full one.
    """
    cfg = config_lib.get_config(arch_id)
    assert isinstance(cfg, DiTConfig)
    rules = pt.dit_rules(cfg, mesh)
    specs = dit.dit_specs(cfg)
    dtype = jnp.dtype(cfg.dtype)
    params_abs = common.abstract_params(specs, dtype)
    params_sh = pt.shardings_for_specs(specs, rules, mesh)
    n_tok = (latent // cfg.patch_size) ** 2
    t = jax.ShapeDtypeStruct((batch,), jnp.float32)
    if cached_step:
        from repro.core.cache import CachePolicy
        from repro.core import cache as cache_lib
        pol = CachePolicy(kind="freqca", interval=5, method="dct",
                          rho=0.0625, high_order=2)
        feat = (batch, n_tok, cfg.d_model)
        state_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            cache_lib.init_state(pol, feat, dtype))
        dp = pt.dp_axes(mesh)
        state_sh = jax.tree.map(
            lambda a: NamedSharding(
                mesh, P(None, dp, *([None] * (len(a.shape) - 2))))
            if len(a.shape) >= 2 else NamedSharding(mesh, P()), state_abs)

        def fn(params, state, tt):
            crf_hat = cache_lib.predict(pol, state, tt[0])
            return dit.dit_from_crf(params, crf_hat, tt, cfg, latent,
                                    latent)
        return StepSpec(name=f"{arch_id}:cached_step", fn=fn,
                        args=(params_abs, state_abs, t),
                        in_shardings=(params_sh, state_sh,
                                      pt.batch_spec(mesh, batch, 1)),
                        out_shardings=None)
    lat = jax.ShapeDtypeStruct((batch, latent, latent, cfg.in_channels),
                               dtype)
    args = [params_abs, lat, t]
    in_sh = [params_sh, pt.batch_spec(mesh, batch, 4),
             pt.batch_spec(mesh, batch, 1)]
    if cfg.text_dim > 0:
        args.append(jax.ShapeDtypeStruct(
            (batch, cfg.n_text_tokens, cfg.text_dim), dtype))
        in_sh.append(pt.batch_spec(mesh, batch, 3))

        def fn(params, latents, tt, text):
            out = dit.dit_forward(params, latents, tt, cfg, text)
            return out.velocity, out.crf
    else:
        def fn(params, latents, tt):
            out = dit.dit_forward(params, latents, tt, cfg)
            return out.velocity, out.crf
    return StepSpec(name=f"{arch_id}:denoise", fn=fn, args=tuple(args),
                    in_shardings=tuple(in_sh), out_shardings=None)
