"""Training launcher.

Two modes:
* ``--arch dit-small`` (default): train the small DiT denoiser on the
  procedural shapes dataset with the rectified-flow loss — this is the
  model used by the paper-claims benchmarks.
* ``--arch <assigned-lm-arch> --reduced``: train the reduced variant of
  an assigned architecture on the synthetic LM stream (smoke-scale).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch dit-small --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as config_lib
from repro.checkpointing import checkpoint
from repro.configs.base import DiTConfig, ModelConfig
from repro.data import synthetic
from repro.diffusion import training as diff_training
from repro.models import common, dit, encdec, transformer
from repro.optim import adamw


def train_dit(cfg: DiTConfig, steps: int, batch: int, ckpt_dir: str,
              seed: int = 0, log_every: int = 20, size: int = 32):
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(seed),
                                jnp.dtype(cfg.dtype))
    opt_cfg = adamw.AdamWConfig(lr=2e-3, warmup_steps=50, total_steps=steps,
                                weight_decay=1e-4)
    opt_state = adamw.init(opt_cfg, params)

    def apply_fn(p, x_t, t):
        return dit.dit_forward(p, x_t, t, cfg).velocity

    @jax.jit
    def step_fn(params, opt_state, batch_latents, rng):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: diff_training.rf_loss(apply_fn, p,
                                            {"latents": batch_latents}, rng),
            has_aux=True)(params)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, {**metrics, **om}

    t0 = time.time()
    for i in range(steps):
        rng = jax.random.key(seed * 7919 + i)
        latents = synthetic.shapes_batch(rng, batch, size=size,
                                         channels=cfg.in_channels)
        params, opt_state, metrics = step_fn(params, opt_state, latents,
                                             jax.random.fold_in(rng, 1))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time() - t0:.1f}s)")
    if ckpt_dir:
        checkpoint.save(ckpt_dir, steps, params, name="dit")
        print("saved", ckpt_dir)
    return params


def train_lm(cfg: ModelConfig, steps: int, batch: int, seq: int,
             ckpt_dir: str, seed: int = 0, log_every: int = 5):
    if cfg.is_encdec:
        specs = encdec.encdec_specs(cfg)
        loss_fn = encdec.loss_fn
    else:
        specs = transformer.lm_specs(cfg)
        loss_fn = transformer.loss_fn
    params = common.init_params(specs, jax.random.key(seed),
                                jnp.dtype(cfg.dtype))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
    opt_state = adamw.init(opt_cfg, params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        return params, opt_state, {**metrics, **om}

    losses = []
    for i in range(steps):
        b = synthetic.lm_batch(jax.random.key(seed * 104729 + i), batch, seq,
                               cfg.vocab_size)
        if cfg.is_encdec:
            b["frames"] = jax.random.normal(
                jax.random.key(i), (batch, seq, cfg.d_model)) * 0.1
        if cfg.n_prefix_tokens > 0:
            b["prefix_embeds"] = jax.random.normal(
                jax.random.key(i), (batch, cfg.n_prefix_tokens, cfg.d_model)
            ) * 0.1
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f}")
    if ckpt_dir:
        checkpoint.save(ckpt_dir, steps, params, name=cfg.arch_id)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    cfg = config_lib.get_config(args.arch)
    if isinstance(cfg, DiTConfig):
        if args.reduced:
            cfg = config_lib.reduced(cfg)
        train_dit(cfg, args.steps, args.batch, args.ckpt)
    else:
        if args.reduced:
            cfg = config_lib.reduced(cfg)
        train_lm(cfg, args.steps, args.batch, args.seq, args.ckpt)


if __name__ == "__main__":
    main()
