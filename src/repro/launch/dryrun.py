import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the
# device count on first init) — per the multi-pod dry-run contract.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract memory / cost / collective stats.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Each run writes results/dryrun/<arch>__<shape>__<mesh>.json with
bytes-per-device, HLO FLOPs/bytes, and per-collective byte counts —
consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro import configs as config_lib
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.roofline import analysis as roofline
from repro.roofline import hlo_analysis


def run_one(arch: str, shape: str, multi_pod: bool,
            out_dir: str = "results/dryrun", verbose: bool = True,
            overrides=None, tag: str = "") -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if tag:
        mesh_name = f"{mesh_name}+{tag}"
    t0 = time.time()
    with mesh:
        if arch in ("flux1-dev", "dit-small"):
            # the paper's own denoiser: shape selects full vs cached step
            spec = steps_lib.build_dit(arch, mesh,
                                       cached_step=(shape == "cached_step"))
        else:
            spec = steps_lib.build(arch, shape, mesh, overrides=overrides)
        jitted = jax.jit(spec.fn,
                         in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = hlo_analysis.analyze(compiled.as_text())
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": roofline.memory_dict(mem),
        # trip-count-aware per-device costs (XLA's cost_analysis counts
        # while bodies once; ours multiplies by known_trip_count)
        "flops": hlo["flops"],
        "bytes_accessed": hlo["bytes_accessed"],
        "collectives": hlo["collectives"],
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape} on {mesh_name}: "
              f"compile={t_compile:.1f}s "
              f"argbytes/dev={record['memory'].get('argument_size_bytes', 0)/1e9:.2f}GB "
              f"temp/dev={record['memory'].get('temp_size_bytes', 0)/1e9:.2f}GB "
              f"flops={record['flops']:.3e}")
        print("  memory_analysis:", record["memory"])
        print("  cost_analysis: flops=%.4e bytes=%.4e"
              % (record["flops"], record["bytes_accessed"]))
        print("  collectives:", json.dumps(record["collectives"]))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{shape}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(config_lib.INPUT_SHAPES)
                    + ["denoise_step", "cached_step", None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    # §Perf iteration knobs
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--moe-impl", default=None,
                    choices=["einsum", "gather", None])
    ap.add_argument("--serve-tp-gb", type=float, default=4.0)
    ap.add_argument("--moe-pad", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = {"microbatch": args.microbatch, "moe_impl": args.moe_impl,
                 "serve_tp_gb": args.serve_tp_gb, "moe_pad": args.moe_pad}

    combos = []
    if args.all:
        for arch in config_lib.ASSIGNED:
            for shape in config_lib.INPUT_SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] skip existing {arch} x {shape} ({mesh_name})")
            continue
        try:
            run_one(arch, shape, args.multi_pod, args.out,
                    overrides=overrides, tag=args.tag)
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"dry-run OK: {len(combos)} combo(s)")


if __name__ == "__main__":
    main()
