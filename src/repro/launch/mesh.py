"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must keep seeing the single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8):
    """Small host-device mesh for CPU integration tests (data x model)."""
    d = min(n_devices, len(jax.devices()))
    assert d % 2 == 0, d
    return jax.make_mesh((d // 2, 2), ("data", "model"))
