"""GQA attention with RoPE, sliding window, KV cache, and cross-attention.

Shapes use [batch, seq, heads, head_dim] throughout.  The KV cache is a
pair of [batch, max_len, kv_heads, head_dim] buffers plus an int32 write
index; decode inserts one token and attends over the valid prefix.  A
sliding-window cache is the same buffer used as a ring — positions are
tracked explicitly so RoPE stays correct past one window.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    s = {
        "wq": ParamSpec((d, nq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wv": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wo": ParamSpec((nq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        s["bq"] = ParamSpec((nq, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((nkv, hd), ("kv_heads", "kv_head_dim"), init="zeros")
        s["bv"] = ParamSpec((nkv, hd), ("kv_heads", "kv_head_dim"), init="zeros")
    return s


class KVCache(NamedTuple):
    k: jnp.ndarray          # [B, max_len, n_kv, hd]
    v: jnp.ndarray          # [B, max_len, n_kv, hd]
    index: jnp.ndarray      # [] int32 — next logical position (monotonic)

    @classmethod
    def zeros(cls, batch, max_len, n_kv, head_dim, dtype):
        return cls(
            k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            index=jnp.zeros((), jnp.int32),
        )

    @classmethod
    def abstract(cls, batch, max_len, n_kv, head_dim, dtype):
        return cls(
            k=jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), dtype),
            v=jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), dtype),
            index=jax.ShapeDtypeStruct((), jnp.int32),
        )


def _qkv(params, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, q_per_kv: int):
    """q:[B,S,Hq,hd] k,v:[B,T,Hkv,hd] mask:[B?,S,T] broadcastable."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    q = q.reshape(b, s, hkv, q_per_kv, hd)
    logits = jnp.einsum("bsgqk,btgk->bgqst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgqst,btgk->bsgqk", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, hd)


def blockwise_sdpa(q, k, v, q_per_kv: int, causal: bool = True,
                   window: int = 0, q_block: int = 0,
                   kv_block: int = 1024):
    """Flash-style blockwise attention with online softmax.

    Memory is O(q_block x kv_block) instead of O(S^2) — the XLA-level
    equivalent of a fused attention kernel, required for the 32k/500k
    input shapes.  q: [B,S,Hq,hd]; k,v: [B,T,Hkv,hd].

    ``q_block=0`` (default) = single query tile: scanning over a
    sharded q-block axis forces GSPMD to replicate attention compute
    across the model axis (measured 8x FLOPs on deepseek prefill,
    §Perf B2) — with one tile only the kv scan remains, the q dimension
    stays sharded, and K/V are gathered once per layer instead of once
    per q block.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    # q_block=0 (default): one query tile — under sequence parallelism
    # the q dim is sharded, and any q-scan would force GSPMD to
    # replicate attention compute across the model axis (§Perf B2/B3)
    qb = min(q_block, s) if q_block else s
    kb = min(kv_block, t)
    assert s % qb == 0 and t % kb == 0, (s, qb, t, kb)
    nq, nk = s // qb, t // kb
    g = q_per_kv
    f32 = jnp.float32
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b, nq, qb, hkv, g, hd)
    kr = k.reshape(b, nk, kb, hkv, hd)
    vr = v.reshape(b, nk, kb, hkv, hd)

    def q_step(_, qi_inp):
        qi, q_tile = qi_inp                       # q_tile [b,qb,hkv,g,hd]
        q_pos = qi * qb + jnp.arange(qb)

        # remat: without this the scan saves O(S^2) logits/probs residuals
        # for backward — the whole point of blockwise attention is that
        # they are recomputed per tile instead.
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, kv_inp):
            acc, m, l = carry
            ki, k_tile, v_tile = kv_inp
            k_pos = ki * kb + jnp.arange(kb)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile.astype(f32),
                                k_tile.astype(f32)) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_tile.astype(f32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), ()

        acc0 = jnp.zeros((b, hkv, g, qb, hd), f32)
        m0 = jnp.full((b, hkv, g, qb), NEG_INF, f32)
        l0 = jnp.zeros((b, hkv, g, qb), f32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return (), out.transpose(0, 3, 1, 2, 4)     # [b,qb,hkv,g,hd]

    _, out = jax.lax.scan(q_step, (),
                          (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, hq, hd)
    return out.astype(q.dtype)


# full-materialisation threshold: above this, use blockwise attention
_BLOCKWISE_MIN_SEQ = 2048


def causal_mask(s: int, window: int = 0, offset: int = 0) -> jnp.ndarray:
    """[1, S, S+offset] causal (optionally sliding-window) mask."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(s + offset)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None]


def self_attention(params, x, cfg: ModelConfig, positions=None, window: int = 0,
                   causal: bool = True, constrain_heads=None):
    """Full-sequence (train / prefill) self-attention.

    ``constrain_heads`` pins [B,S,H,hd] projections to the TP layout
    (same Megatron-SP switch as the FFN hook — without it, SP-sharded
    inputs make every attention weight gradient a full-size f32
    partial)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(params, x, cfg, positions)
    if constrain_heads is not None:
        q = constrain_heads(q)
    win = window or cfg.sliding_window
    if s >= _BLOCKWISE_MIN_SEQ:
        out = blockwise_sdpa(q, k, v, cfg.q_per_kv, causal=causal, window=win)
    else:
        if causal:
            mask = causal_mask(s, window=win)
        else:
            mask = jnp.ones((1, s, s), bool)
        out = _sdpa(q, k, v, mask, cfg.q_per_kv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def decode_self_attention(params, x, cfg: ModelConfig, cache: KVCache,
                          window: int = 0):
    """One-token decode against a KV cache.

    ``window > 0`` treats the cache as a ring buffer of that size; the
    logical position keeps increasing so RoPE stays absolute.
    """
    b, s, _ = x.shape
    assert s == 1, "decode step consumes exactly one new token"
    max_len = cache.k.shape[1]
    pos = cache.index
    positions = jnp.broadcast_to(pos[None], (b, 1)).astype(jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, positions)
    slot = jnp.where(window > 0, pos % max_len, pos).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    kpos = jnp.arange(max_len)
    if window > 0:
        # ring: slot i holds logical position p iff p = largest value
        # <= pos with p % max_len == i
        logical = kpos + (pos - kpos) // max_len * max_len
        valid = (logical >= 0) & (logical <= pos) & (logical > pos - window)
    else:
        valid = kpos <= pos
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, max_len))
    out = _sdpa(q, k, v, mask, cfg.q_per_kv)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, KVCache(k=k, v=v, index=pos + 1)


# ---------------------------------------------------------------------------
# cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------

def cross_attn_specs(cfg: ModelConfig):
    return attn_specs(cfg, cross=True)


def cross_attention(params, x, memory, cfg: ModelConfig):
    """x: [B,S,d] decoder states; memory: [B,T,d] encoder output."""
    b, s, _ = x.shape
    t = memory.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", memory, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", memory, params["wv"].astype(x.dtype))
    if s * t >= _BLOCKWISE_MIN_SEQ ** 2:
        out = blockwise_sdpa(q, k, v, cfg.q_per_kv, causal=False)
    else:
        mask = jnp.ones((1, s, t), bool)
        out = _sdpa(q, k, v, mask, cfg.q_per_kv)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
