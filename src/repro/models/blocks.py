"""Decoder blocks + layer stacks.

A block = pre-norm mixer (attention or Mamba2 SSD) + pre-norm FFN (dense
SwiGLU or MoE).  Homogeneous stacks are a single ``lax.scan`` over stacked
layer params (small HLO, fast compile — essential for the 512-device
dry-run).  Hybrid (Jamba-style) stacks scan over *groups* of
``attn_every`` layers with the group body unrolled, so the 1:7
mamba:attention interleave and alternating dense/MoE FFNs live inside one
scanned group.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp, moe, ssm
from repro.models.common import ParamSpec


class BlockAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    router_z_loss: jnp.ndarray
    drop_fraction: jnp.ndarray

    @classmethod
    def zero(cls):
        z = jnp.zeros((), jnp.float32)
        return cls(z, z, z)

    def __add__(self, other):
        return BlockAux(*[a + b for a, b in zip(self, other, strict=True)])


def block_specs(cfg: ModelConfig, kind: str, is_moe: bool):
    s: Dict[str, Any] = {"norm1": common.rmsnorm_specs(cfg.d_model)}
    if kind == "attn":
        s["attn"] = attention.attn_specs(cfg)
    else:
        s["ssm"] = ssm.ssm_specs(cfg)
    if is_moe or cfg.d_ff > 0:
        s["norm2"] = common.rmsnorm_specs(cfg.d_model)
        s["ffn"] = moe.moe_specs(cfg) if is_moe else mlp.mlp_specs(cfg)
    return s


def _mixer_full(params, x, cfg: ModelConfig, kind: str, window: int,
                causal: bool = True, constrain_heads=None):
    if kind == "attn":
        return attention.self_attention(params["attn"], x, cfg, window=window,
                                        causal=causal,
                                        constrain_heads=constrain_heads)
    return ssm.ssm_block(params["ssm"], x, cfg)


def _ffn(params, x, cfg: ModelConfig, is_moe: bool,
         constrain_ffn=None) -> Tuple[jnp.ndarray, BlockAux]:
    if is_moe:
        fn = moe.moe_ffn_gather if cfg.moe.impl == "gather" else moe.moe_ffn
        y, aux = fn(params["ffn"], x, cfg)
        return y, BlockAux(aux.load_balance_loss, aux.router_z_loss,
                           aux.drop_fraction)
    return mlp.mlp(params["ffn"], x, constrain_ffn=constrain_ffn), \
        BlockAux.zero()


def block_full(params, x, cfg: ModelConfig, kind: str, is_moe: bool,
               window: int = 0, causal: bool = True, constrain_ffn=None,
               constrain_heads=None):
    """Full-sequence block (train / prefill)."""
    h = x + _mixer_full(params, common.rmsnorm(params["norm1"], x, cfg.norm_eps),
                        cfg, kind, window, causal,
                        constrain_heads=constrain_heads)
    if "ffn" not in params:
        return h, BlockAux.zero()
    f, aux = _ffn(params, common.rmsnorm(params["norm2"], h, cfg.norm_eps),
                  cfg, is_moe, constrain_ffn=constrain_ffn)
    return h + f, aux


def block_decode(params, x, cfg: ModelConfig, kind: str, is_moe: bool,
                 cache, window: int = 0):
    """One-token decode block."""
    hin = common.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        y, cache = attention.decode_self_attention(params["attn"], hin, cfg,
                                                   cache, window=window)
    else:
        y, cache = ssm.ssm_decode_step(params["ssm"], hin, cfg, cache)
    h = x + y
    if "ffn" not in params:
        return h, cache, BlockAux.zero()
    f, aux = _ffn(params, common.rmsnorm(params["norm2"], h, cfg.norm_eps),
                  cfg, is_moe)
    return h + f, cache, aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _layer_plan(cfg: ModelConfig):
    """Return (group_size, n_groups, [(kind, is_moe)] per position-in-group).

    Homogeneous stacks use group_size == 1 scanned n_layers times; hybrid
    stacks group ``attn_every`` layers.
    """
    kinds = cfg.layer_kinds()
    moes = tuple(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
    if cfg.family == "hybrid" and cfg.attn_every > 0:
        gs = cfg.attn_every
        # MoE cadence must align with the group for the scan to be valid
        assert cfg.n_layers % gs == 0
        plan = tuple(zip(kinds[:gs], moes[:gs], strict=True))
        for g in range(cfg.n_layers // gs):
            assert tuple(zip(kinds[g * gs:(g + 1) * gs],
                             moes[g * gs:(g + 1) * gs],
                             strict=True)) == plan
        return gs, cfg.n_layers // gs, plan
    # homogeneous check
    assert all(k == kinds[0] for k in kinds)
    assert all(m == moes[0] for m in moes)
    return 1, cfg.n_layers, ((kinds[0], moes[0]),)


def stack_specs(cfg: ModelConfig):
    gs, ng, plan = _layer_plan(cfg)
    group = {f"l{i}": block_specs(cfg, kind, is_moe)
             for i, (kind, is_moe) in enumerate(plan)}
    return common.stack_specs(group, ng)


def stack_cache_abstract(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Abstract (ShapeDtypeStruct) decode cache for the whole stack."""
    gs, ng, plan = _layer_plan(cfg)
    def one(kind):
        if kind == "attn":
            c = attention.KVCache.abstract(batch, max_len, cfg.n_kv_heads,
                                           cfg.head_dim, dtype)
        else:
            c = ssm.SSMCache.abstract(batch, cfg, dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((ng,) + s.shape, s.dtype), c)
    return {f"l{i}": one(kind) for i, (kind, _) in enumerate(plan)}


def stack_cache_axes(cfg: ModelConfig):
    """Logical-axis tuples mirroring ``stack_cache_abstract`` structure."""
    gs, ng, plan = _layer_plan(cfg)

    def one(kind):
        if kind == "attn":
            return attention.KVCache(
                k=("layer", "batch", "len", "kv_heads", "kv_head_dim"),
                v=("layer", "batch", "len", "kv_heads", "kv_head_dim"),
                index=("layer",))
        return ssm.SSMCache(
            conv=("layer", "batch", None, "inner"),
            state=("layer", "batch", "ssm_heads", None, None))
    return {f"l{i}": one(kind) for i, (kind, _) in enumerate(plan)}


def stack_cache_zeros(cfg: ModelConfig, batch: int, max_len: int, dtype):
    gs, ng, plan = _layer_plan(cfg)
    def one(kind):
        if kind == "attn":
            c = attention.KVCache.zeros(batch, max_len, cfg.n_kv_heads,
                                        cfg.head_dim, dtype)
        else:
            c = ssm.SSMCache.zeros(batch, cfg, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (ng,) + a.shape), c)
    return {f"l{i}": one(kind) for i, (kind, _) in enumerate(plan)}


def stack_full(params, x, cfg: ModelConfig, window: int = 0,
               causal: bool = True, remat: Optional[bool] = None,
               constrain=None, constrain_ffn=None, constrain_heads=None):
    """Run the full layer stack over a sequence.

    Returns (hidden, aux).  ``hidden`` is the Cumulative Residual Feature
    (CRF) of the paper — the input embedding plus every residual update.

    ``constrain`` (optional) re-pins the activation sharding on the scan
    carry each group — without it GSPMD may solve for replicated
    activations across the batch axis.
    """
    gs, ng, plan = _layer_plan(cfg)
    use_remat = cfg.remat if remat is None else remat
    if constrain is None:
        constrain = lambda t: t
    x = constrain(x)

    def group_body(h, group_params):
        aux = BlockAux.zero()
        for i, (kind, is_moe) in enumerate(plan):
            h, a = block_full(group_params[f"l{i}"], h, cfg, kind, is_moe,
                              window=window, causal=causal,
                              constrain_ffn=constrain_ffn,
                              constrain_heads=constrain_heads)
            h = constrain(h)
            aux = aux + a
        return h, aux

    body = jax.checkpoint(group_body) if use_remat else group_body
    h, aux = jax.lax.scan(lambda c, p: body(c, p), x, params)
    aux = jax.tree.map(lambda a: jnp.mean(a) / len(plan), aux)
    return h, BlockAux(*aux)


def stack_decode(params, x, cfg: ModelConfig, cache, window: int = 0):
    """One-token decode through the stack. Returns (hidden, new_cache, aux)."""
    gs, ng, plan = _layer_plan(cfg)

    def group_body(h, inp):
        group_params, group_cache = inp
        aux = BlockAux.zero()
        new_cache = {}
        for i, (kind, is_moe) in enumerate(plan):
            h, c, a = block_decode(group_params[f"l{i}"], h, cfg, kind, is_moe,
                                   group_cache[f"l{i}"], window=window)
            new_cache[f"l{i}"] = c
            aux = aux + a
        return h, (new_cache, aux)

    h, (new_cache, aux) = jax.lax.scan(group_body, x, (params, cache))
    aux = jax.tree.map(lambda a: jnp.mean(a) / len(plan), aux)
    return h, new_cache, BlockAux(*aux)
