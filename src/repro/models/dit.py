"""Diffusion transformers — the paper's model family.

Two denoisers:

* ``dit_*`` — FLUX-like MMDiT: optional dual-stream (image+text) "double"
  blocks followed by single-stream joint blocks, AdaLN-zero modulation,
  rectified-flow velocity output.  ``dit_forward`` returns the Cumulative
  Residual Feature (CRF) of the image stream next to the velocity, and
  ``dit_from_crf`` maps a *predicted* CRF straight to a velocity — the
  FreqCa skip path (everything but the final layer is bypassed).

* ``backbone_*`` — wraps any assigned ``ModelConfig`` architecture
  (dense/MoE/SSM/hybrid) as a continuous-latent denoiser: patchify +
  time-conditioning around its residual stack.  This is how FreqCa is
  exercised on the assigned architectures (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DiTConfig, ModelConfig
from repro.kernels import ops
from repro.models import attention, blocks, common
from repro.models.common import ParamSpec


class DenoiserOutput(NamedTuple):
    velocity: jnp.ndarray      # [B, H, W, C]
    crf: jnp.ndarray           # [B, S_img, d] image-stream CRF


def timestep_embedding(t: jnp.ndarray, dim: int, max_period: float = 10000.0):
    """t: [B] in [0, 1] -> [B, dim] sinusoidal features."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * 1000.0 * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def _pos_embedding(s: int, d: int):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None]
    angles = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], -1)


def patchify(latents: jnp.ndarray, p: int):
    b, h, w, c = latents.shape
    x = latents.reshape(b, h // p, p, w // p, p, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, (h // p) * (w // p),
                                                 p * p * c)


def unpatchify(tokens: jnp.ndarray, h: int, w: int, p: int, c: int):
    b = tokens.shape[0]
    x = tokens.reshape(b, h // p, w // p, p, p, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h, w, c)


# ---------------------------------------------------------------------------
# MMDiT blocks
# ---------------------------------------------------------------------------

def _attn_specs(d: int, n_heads: int):
    hd = d // n_heads
    return {
        "wq": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wo": ParamSpec((n_heads, hd, d), ("heads", "head_dim", "embed")),
        "q_norm": ParamSpec((hd,), (None,), init="ones"),
        "k_norm": ParamSpec((hd,), (None,), init="ones"),
    }


def _mlp_specs(d: int, f: int):
    return {"wi": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed"))}


def _mod_specs(d: int, n: int):
    return {"kernel": ParamSpec((d, n * d), ("embed", None), init="zeros"),
            "bias": ParamSpec((n * d,), (None,), init="zeros")}


def _modulation(params, cond, n: int):
    """cond: [B, d] -> n chunks of [B, 1, d]."""
    m = jax.nn.silu(cond) @ params["kernel"].astype(cond.dtype) \
        + params["bias"].astype(cond.dtype)
    return jnp.split(m[:, None, :], n, axis=-1)


def _qkv_heads(p, x, n_heads):
    b, s, d = x.shape
    hd = d // n_heads
    def norm(v, scale):
        return common.layernorm(v, scale=scale)
    q = norm((x @ p["wq"].astype(x.dtype).reshape(d, d)).reshape(b, s, n_heads, hd),
             p["q_norm"])
    k = norm((x @ p["wk"].astype(x.dtype).reshape(d, d)).reshape(b, s, n_heads, hd),
             p["k_norm"])
    v = (x @ p["wv"].astype(x.dtype).reshape(d, d)).reshape(b, s, n_heads, hd)
    return q, k, v


# flash-kernel threshold: below this, full-logits attention is cheaper
# than the kernel's tiling overhead (cf. attention._BLOCKWISE_MIN_SEQ)
_FLASH_MIN_SEQ = 1024


def _flash_ok(s: int) -> bool:
    from repro.kernels import flash_attention as fa
    return s >= _FLASH_MIN_SEQ and fa.dispatch_ok(s)


def _joint_attention(q, k, v, p_out, x_dtype):
    b, s, nh, hd = q.shape
    if ops.use_pallas() and _flash_ok(s):
        # non-causal flash attention: logits tiles stay in VMEM instead
        # of materialising the [B, H, S, S] tensor (q_per_kv=1 — the
        # joint streams share full MHA)
        out = ops.flash(q, k, v, 1, causal=False)
    else:
        logits = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32),
                            k.astype(jnp.float32)) / math.sqrt(hd)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthk->bshk", probs, v)
    return jnp.einsum("bshk,hkd->bsd", out, p_out.astype(x_dtype))


def single_block_specs(cfg: DiTConfig):
    return {"mod": _mod_specs(cfg.d_model, 6),
            "attn": _attn_specs(cfg.d_model, cfg.n_heads),
            "mlp": _mlp_specs(cfg.d_model, cfg.d_ff)}


def single_block(params, x, cond, cfg: DiTConfig):
    """Single-stream joint block with AdaLN-zero."""
    sh1, sc1, g1, sh2, sc2, g2 = _modulation(params["mod"], cond, 6)
    h = common.layernorm(x, cfg.norm_eps) * (1 + sc1) + sh1
    q, k, v = _qkv_heads(params["attn"], h, cfg.n_heads)
    x = x + g1 * _joint_attention(q, k, v, params["attn"]["wo"], x.dtype)
    h = common.layernorm(x, cfg.norm_eps) * (1 + sc2) + sh2
    y = jax.nn.gelu(h @ params["mlp"]["wi"].astype(x.dtype))
    x = x + g2 * (y @ params["mlp"]["wo"].astype(x.dtype))
    return x


def double_block_specs(cfg: DiTConfig):
    return {"img": single_block_specs(cfg), "txt": single_block_specs(cfg)}


def double_block(params, img, txt, cond, cfg: DiTConfig):
    """Dual-stream MMDiT block: separate params, joint attention."""
    outs = {}
    streams = {"img": img, "txt": txt}
    qkvs = {}
    mods = {}
    for name in ("img", "txt"):
        p = params[name]
        mods[name] = _modulation(p["mod"], cond, 6)
        sh1, sc1 = mods[name][0], mods[name][1]
        h = common.layernorm(streams[name], cfg.norm_eps) * (1 + sc1) + sh1
        qkvs[name] = _qkv_heads(p["attn"], h, cfg.n_heads)
    s_txt = txt.shape[1]
    q = jnp.concatenate([qkvs["txt"][0], qkvs["img"][0]], axis=1)
    k = jnp.concatenate([qkvs["txt"][1], qkvs["img"][1]], axis=1)
    v = jnp.concatenate([qkvs["txt"][2], qkvs["img"][2]], axis=1)
    for name in ("img", "txt"):
        p = params[name]
        _, _, g1, sh2, sc2, g2 = mods[name]
        attn_out = _joint_attention(q, k, v, p["attn"]["wo"], img.dtype)
        part = attn_out[:, s_txt:] if name == "img" else attn_out[:, :s_txt]
        x = streams[name] + g1 * part
        h = common.layernorm(x, cfg.norm_eps) * (1 + sc2) + sh2
        y = jax.nn.gelu(h @ p["mlp"]["wi"].astype(x.dtype))
        outs[name] = x + g2 * (y @ p["mlp"]["wo"].astype(x.dtype))
    return outs["img"], outs["txt"]


def dit_specs(cfg: DiTConfig):
    pdim = cfg.patch_size * cfg.patch_size * cfg.in_channels
    s: Dict[str, Any] = {
        "patch_proj": common.dense_specs(pdim, cfg.d_model, None, "embed",
                                         use_bias=True),
        "time_mlp1": common.dense_specs(cfg.time_embed_dim, cfg.d_model,
                                        None, "embed", use_bias=True),
        "time_mlp2": common.dense_specs(cfg.d_model, cfg.d_model,
                                        "embed", None, use_bias=True),
        "single": common.stack_specs(single_block_specs(cfg), cfg.n_layers),
        "final_mod": _mod_specs(cfg.d_model, 2),
        "final_proj": ParamSpec((cfg.d_model, pdim), ("embed", None),
                                init="zeros"),
    }
    if cfg.n_double > 0:
        s["double"] = common.stack_specs(double_block_specs(cfg), cfg.n_double)
    if cfg.text_dim > 0:
        s["text_proj"] = common.dense_specs(cfg.text_dim, cfg.d_model, None,
                                            "embed", use_bias=True)
    return s


def _time_cond(params, t, cfg: DiTConfig, dtype):
    emb = timestep_embedding(t, cfg.time_embed_dim).astype(dtype)
    h = jax.nn.silu(common.dense(params["time_mlp1"], emb))
    return common.dense(params["time_mlp2"], h)


def dit_forward(params, latents: jnp.ndarray, t: jnp.ndarray,
                cfg: DiTConfig,
                text_embeds: Optional[jnp.ndarray] = None) -> DenoiserOutput:
    """latents: [B,H,W,C]; t: [B] in [0,1]; text_embeds: [B,T,text_dim]."""
    b, h, w, c = latents.shape
    dtype = jnp.dtype(cfg.dtype)
    x = patchify(latents.astype(dtype), cfg.patch_size)
    x = common.dense(params["patch_proj"], x)
    s_img = x.shape[1]
    x = x + _pos_embedding(s_img, cfg.d_model).astype(dtype)[None]
    cond = _time_cond(params, t, cfg, dtype)

    txt = None
    if cfg.text_dim > 0 and text_embeds is not None:
        txt = common.dense(params["text_proj"], text_embeds.astype(dtype))

    if cfg.n_double > 0 and txt is not None:
        def dbody(carry, layer_params):
            img_h, txt_h = carry
            img_h, txt_h = double_block(layer_params, img_h, txt_h,
                                        cond[:, 0] if cond.ndim == 3 else cond,
                                        cfg)
            return (img_h, txt_h), ()
        (x, txt), _ = jax.lax.scan(dbody, (x, txt), params["double"])

    if txt is not None:
        s_txt = txt.shape[1]
        x = jnp.concatenate([txt, x], axis=1)
    else:
        s_txt = 0

    def sbody(h_tok, layer_params):
        return single_block(layer_params, h_tok, cond, cfg), ()

    x, _ = jax.lax.scan(sbody, x, params["single"])
    crf = x[:, s_txt:]
    velocity = _final_layer(params, crf, cond, cfg, h, w)
    return DenoiserOutput(velocity=velocity, crf=crf)


def _final_layer(params, crf, cond, cfg: DiTConfig, h: int, w: int):
    sh, sc = _modulation(params["final_mod"], cond, 2)
    y = common.layernorm(crf, cfg.norm_eps) * (1 + sc) + sh
    y = y @ params["final_proj"].astype(crf.dtype)
    return unpatchify(y, h, w, cfg.patch_size, cfg.in_channels)


def dit_from_crf(params, crf: jnp.ndarray, t: jnp.ndarray, cfg: DiTConfig,
                 h: int, w: int) -> jnp.ndarray:
    """FreqCa skip path: predicted CRF -> velocity (final layer only)."""
    cond = _time_cond(params, t, cfg, crf.dtype)
    return _final_layer(params, crf, cond, cfg, h, w)


# ---------------------------------------------------------------------------
# assigned-architecture backbones as denoisers
# ---------------------------------------------------------------------------

def backbone_denoiser_specs(cfg: ModelConfig, patch_size: int = 2,
                            in_channels: int = 4, time_dim: int = 256):
    pdim = patch_size * patch_size * in_channels
    return {
        "patch_proj": common.dense_specs(pdim, cfg.d_model, None, "embed",
                                         use_bias=True),
        "time_mlp1": common.dense_specs(time_dim, cfg.d_model, None, "embed",
                                        use_bias=True),
        "time_mlp2": common.dense_specs(cfg.d_model, cfg.d_model, "embed",
                                        None, use_bias=True),
        "stack": blocks.stack_specs(cfg),
        "final_norm": common.rmsnorm_specs(cfg.d_model),
        "final_proj": ParamSpec((cfg.d_model, pdim), ("embed", None),
                                init="zeros"),
    }


def backbone_denoiser_forward(params, latents, t, cfg: ModelConfig,
                              patch_size: int = 2, time_dim: int = 256
                              ) -> DenoiserOutput:
    b, hh, ww, c = latents.shape
    dtype = jnp.dtype(cfg.dtype)
    x = patchify(latents.astype(dtype), patch_size)
    x = common.dense(params["patch_proj"], x)
    x = x + _pos_embedding(x.shape[1], cfg.d_model).astype(dtype)[None]
    emb = timestep_embedding(t, time_dim).astype(dtype)
    temb = common.dense(params["time_mlp2"],
                        jax.nn.silu(common.dense(params["time_mlp1"], emb)))
    x = x + temb[:, None, :]
    h, _ = blocks.stack_full(params["stack"], x, cfg, causal=False)
    y = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    y = y @ params["final_proj"].astype(y.dtype)
    velocity = unpatchify(y, hh, ww, patch_size, c)
    return DenoiserOutput(velocity=velocity, crf=h)


def backbone_denoiser_from_crf(params, crf, cfg: ModelConfig, h: int, w: int,
                               patch_size: int = 2, in_channels: int = 4):
    y = common.rmsnorm(params["final_norm"], crf, cfg.norm_eps)
    y = y @ params["final_proj"].astype(y.dtype)
    return unpatchify(y, h, w, patch_size, in_channels)
