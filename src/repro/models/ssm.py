"""Mamba2 (SSD — state-space duality) block.

Training/prefill uses the chunked SSD form [arXiv:2405.21060]: quadratic
attention-like compute inside fixed-size chunks (MXU-friendly matmuls) and
a `lax.scan` over chunk states for the linear recurrence — sequential only
in the chunk dimension, parallel in (batch, heads).  Decode uses the O(1)
recurrent state update.  Heads are sharded on the "model" mesh axis; the
scan carries no cross-device state, so the recurrence adds no collectives.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import common
from repro.models.common import ParamSpec


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm or SSMConfig()
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.d_state
    return ssm, d_inner, n_heads, conv_dim


def ssm_specs(cfg: ModelConfig):
    ssm, d_inner, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    return {
        # projects to [z (gate), x, B, C, dt]
        "in_proj": ParamSpec(
            (d, 2 * d_inner + 2 * ssm.d_state + n_heads), ("embed", "inner")),
        "conv_kernel": ParamSpec((ssm.conv_width, conv_dim), (None, "inner"),
                                 scale=0.1),
        "conv_bias": ParamSpec((conv_dim,), ("inner",), init="zeros"),
        "A_log": ParamSpec((n_heads,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamSpec((n_heads,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((n_heads,), ("ssm_heads",), init="ones"),
        "norm_scale": ParamSpec((d_inner,), ("inner",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("inner", "embed")),
    }


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # [B, conv_width-1, conv_dim] — last inputs
    state: jnp.ndarray   # [B, H, P, N] recurrent state

    @classmethod
    def zeros(cls, batch, cfg: ModelConfig, dtype):
        ssm, d_inner, n_heads, conv_dim = _dims(cfg)
        return cls(
            conv=jnp.zeros((batch, ssm.conv_width - 1, conv_dim), dtype),
            state=jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state),
                            jnp.float32),
        )

    @classmethod
    def abstract(cls, batch, cfg: ModelConfig, dtype):
        ssm, d_inner, n_heads, conv_dim = _dims(cfg)
        return cls(
            conv=jax.ShapeDtypeStruct((batch, ssm.conv_width - 1, conv_dim),
                                      dtype),
            state=jax.ShapeDtypeStruct(
                (batch, n_heads, ssm.head_dim, ssm.d_state), jnp.float32),
        )


def _split_proj(params, x, cfg: ModelConfig):
    ssm, d_inner, n_heads, _ = _dims(cfg)
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * ssm.d_state], axis=-1)
    return z, xbc, dt


def _causal_conv(params, xbc, cfg: ModelConfig, prefix=None):
    """Depthwise causal conv over [B, S, C]; prefix = [B, W-1, C] history."""
    ssm = cfg.ssm or SSMConfig()
    w = ssm.conv_width
    if prefix is None:
        prefix = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([prefix, xbc], axis=1)
    kernel = params["conv_kernel"].astype(xbc.dtype)
    out = sum(xp[:, i:i + xbc.shape[1], :] * kernel[i] for i in range(w))
    out = out + params["conv_bias"].astype(xbc.dtype)
    return jax.nn.silu(out), xp[:, -(w - 1):, :]


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: [b, s, h, p]; dt: [b, s, h] (>=0); A: [h] (negative); B, C:
    [b, s, n].  Returns y: [b, s, h, p] and final state [b, h, p, n].

    The whole per-chunk computation (including the [q, q, h] intra-chunk
    decay) lives INSIDE the scan body, so peak memory is O(b·q²·h) for
    one chunk — materialising it for all chunks at once is what blew a
    Jamba-scale dry-run past 500 GB/device.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    f32 = jnp.float32

    xc = jnp.moveaxis(x.astype(f32).reshape(b, nc, q, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.astype(f32).reshape(b, nc, q, h), 1, 0)
    Bc = jnp.moveaxis(B.astype(f32).reshape(b, nc, q, n), 1, 0)
    Cc = jnp.moveaxis(C.astype(f32).reshape(b, nc, q, n), 1, 0)
    A = A.astype(f32)
    mask = jnp.tril(jnp.ones((q, q), bool))

    # remat: the [b,q,q,h] intra-chunk decay matrix is needed by the
    # backward of the einsums — without checkpointing the scan saves it
    # for EVERY chunk (Jamba-scale: ~0.5 TB/device); recompute instead.
    @jax.checkpoint
    def step(state, inp):
        x_k, dt_k, B_k, C_k = inp                 # [b,q,...] one chunk
        dA_cum = jnp.cumsum(dt_k * A, axis=1)     # [b, q, h]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j), j <= i
        diff = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]   # [b,q,q,h]
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bin,bjn->bij", C_k, B_k)              # [b,q,q]
        y = jnp.einsum("bij,bijh,bjh,bjhp->bihp", cb, L, dt_k, x_k)
        # carried-state contribution
        y += jnp.einsum("bin,bhpn,bih->bihp", C_k, state, jnp.exp(dA_cum))
        # state update
        decay_out = jnp.exp(dA_cum[:, -1:, :] - dA_cum)        # [b,q,h]
        st_new = jnp.einsum("bjh,bjn,bjhp->bhpn",
                            dt_k * decay_out, B_k, x_k)
        state = state * jnp.exp(dA_cum[:, -1, :])[:, :, None, None] + st_new
        return state, y

    init = jnp.zeros((b, h, p, n), f32)
    final_state, ys = jax.lax.scan(step, init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_recurrent_step(x, dt, A, B, C, state):
    """Single-token recurrence.  x:[b,h,p] dt:[b,h] B,C:[b,n] state:[b,h,p,n]."""
    f32 = jnp.float32
    x, dt, B, C = (t.astype(f32) for t in (x, dt, B, C))
    dA = jnp.exp(dt * A.astype(f32))                             # [b, h]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B, x)
    state = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, C)
    return y, state


def ssm_block(params, x, cfg: ModelConfig):
    """Full-sequence Mamba2 mixer. x: [B, S, d] -> [B, S, d]."""
    ssm, d_inner, n_heads, _ = _dims(cfg)
    b, s, _ = x.shape
    z, xbc, dt = _split_proj(params, x, cfg)
    xbc, _ = _causal_conv(params, xbc, cfg)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + ssm.d_state], axis=-1)
    xs = xs.reshape(b, s, n_heads, ssm.head_dim)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    y, _ = ssd_chunked(xs, dt, A, B, C, ssm.chunk)
    y = y + xs * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = common.rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z),
                       cfg.norm_eps)
    return y @ params["out_proj"].astype(x.dtype)


def ssm_decode_step(params, x, cfg: ModelConfig, cache: SSMCache):
    """One-token decode. x: [B, 1, d] -> ([B, 1, d], SSMCache)."""
    ssm, d_inner, n_heads, conv_dim = _dims(cfg)
    b = x.shape[0]
    z, xbc, dt = _split_proj(params, x, cfg)
    xbc, conv_state = _causal_conv(params, xbc, cfg, prefix=cache.conv)
    xs, B, C = jnp.split(xbc[:, 0], [d_inner, d_inner + ssm.d_state], axis=-1)
    xs = xs.reshape(b, n_heads, ssm.head_dim)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    y, state = ssd_recurrent_step(xs, dtv, A, B, C, cache.state)
    y = y.astype(x.dtype) + xs * params["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, d_inner)
    y = common.rmsnorm({"scale": params["norm_scale"]},
                       y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, SSMCache(conv=conv_state, state=state)
