from repro.models import attention, blocks, common, dit, encdec, mlp, moe, ssm, transformer  # noqa: F401
