"""SwiGLU feed-forward block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec


def mlp_specs(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "ffn")),
        "wi_up": ParamSpec((d, f), ("embed", "ffn")),
        "wo": ParamSpec((f, d), ("ffn", "embed")),
    }


def mlp(params, x, constrain_ffn=None):
    """``constrain_ffn`` pins the [B, S, d_ff] hidden to the TP layout —
    under sequence parallelism GSPMD otherwise keeps S-sharding through
    the FFN, which turns every weight gradient into a full-size f32
    partial + all-reduce (Megatron-SP switches to TP inside the block
    and back to SP at the boundary; this hook is that switch)."""
    gate = jax.nn.silu(x @ params["wi_gate"].astype(x.dtype))
    up = x @ params["wi_up"].astype(x.dtype)
    h = gate * up
    if constrain_ffn is not None:
        h = constrain_ffn(h)
    return h @ params["wo"].astype(x.dtype)
