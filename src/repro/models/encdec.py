"""Encoder-decoder transformer (SeamlessM4T-style speech-to-text backbone).

The audio frontend (mel-spectrogram + conv feature extractor) is stubbed
per the assignment: the encoder consumes precomputed frame embeddings
[B, T, d_model].  The decoder is a causal text decoder with
cross-attention into the encoder memory.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks, common, mlp
from repro.models.common import ParamSpec


class EncDecOutput(NamedTuple):
    logits: jnp.ndarray
    crf: jnp.ndarray           # decoder CRF
    memory: jnp.ndarray        # encoder output


def _dec_block_specs(cfg: ModelConfig):
    return {
        "norm1": common.rmsnorm_specs(cfg.d_model),
        "self_attn": attention.attn_specs(cfg),
        "norm_x": common.rmsnorm_specs(cfg.d_model),
        "cross_attn": attention.cross_attn_specs(cfg),
        "norm2": common.rmsnorm_specs(cfg.d_model),
        "ffn": mlp.mlp_specs(cfg),
    }


def encdec_specs(cfg: ModelConfig):
    enc_cfg = cfg  # same width; depth differs
    return {
        "enc_proj": common.dense_specs(cfg.d_model, cfg.d_model, "embed", None),
        "encoder": common.stack_specs(
            blocks.block_specs(cfg, "attn", False), cfg.n_enc_layers),
        "enc_norm": common.rmsnorm_specs(cfg.d_model),
        "embed": common.embed_specs(cfg.vocab_size, cfg.d_model),
        "decoder": common.stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "final_norm": common.rmsnorm_specs(cfg.d_model),
        "head": {"kernel": ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), scale=0.02)},
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig, constrain=None):
    """frames: [B, T, d_model] precomputed frontend embeddings."""
    if constrain is None:
        constrain = lambda t: t
    x = constrain(common.dense(params["enc_proj"],
                               frames.astype(jnp.dtype(cfg.dtype))))

    def body(h, layer_params):
        h, _ = blocks.block_full(layer_params, h, cfg, "attn", False,
                                 causal=False)
        return constrain(h), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return common.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _dec_block(layer_params, h, memory, cfg: ModelConfig, cache=None,
               window: int = 0):
    hin = common.rmsnorm(layer_params["norm1"], h, cfg.norm_eps)
    if cache is None:
        h = h + attention.self_attention(layer_params["self_attn"], hin, cfg,
                                         window=window)
        new_cache = None
    else:
        y, new_cache = attention.decode_self_attention(
            layer_params["self_attn"], hin, cfg, cache, window=window)
        h = h + y
    hx = common.rmsnorm(layer_params["norm_x"], h, cfg.norm_eps)
    h = h + attention.cross_attention(layer_params["cross_attn"], hx, memory,
                                      cfg)
    h2 = common.rmsnorm(layer_params["norm2"], h, cfg.norm_eps)
    return h + mlp.mlp(layer_params["ffn"], h2), new_cache


def forward(params, frames: jnp.ndarray, tokens: jnp.ndarray,
            cfg: ModelConfig, window: int = 0) -> EncDecOutput:
    memory = encode(params, frames, cfg)
    x = common.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def body(h, layer_params):
        h, _ = _dec_block(layer_params, h, memory, cfg, window=window)
        return h, ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, x, params["decoder"])
    logits = common.rmsnorm(params["final_norm"], h, cfg.norm_eps) @ \
        params["head"]["kernel"].astype(h.dtype)
    return EncDecOutput(logits=logits, crf=h, memory=memory)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            constrain=None, constrain_ffn=None, constrain_heads=None):
    from repro.models import transformer as _tf
    if constrain is None:
        constrain = lambda t: t
    memory = encode(params, batch["frames"], cfg, constrain=constrain)
    x = constrain(common.embed(params["embed"], batch["tokens"]).astype(
        jnp.dtype(cfg.dtype)))

    def body(h, layer_params):
        h, _ = _dec_block(layer_params, h, memory, cfg)
        return constrain(h), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, x, params["decoder"])
    hn = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    # 256k-vocab logits never materialise (sequence-chunked CE)
    loss = _tf.chunked_cross_entropy(params, hn, batch["labels"], cfg)
    return loss, {"loss": loss}


def decode_cache_abstract(cfg: ModelConfig, batch: int, max_len: int, dtype):
    c = attention.KVCache.abstract(batch, max_len, cfg.n_kv_heads,
                                   cfg.head_dim, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype), c)


def decode_cache_zeros(cfg: ModelConfig, batch: int, max_len: int, dtype):
    c = attention.KVCache.zeros(batch, max_len, cfg.n_kv_heads, cfg.head_dim,
                                dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c)


def decode_step(params, tokens: jnp.ndarray, memory: jnp.ndarray, cache,
                cfg: ModelConfig, window: int = 0):
    """One-token decode. tokens: [B,1]; memory: [B,T,d] encoder output."""
    x = common.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def body(h, inp):
        layer_params, layer_cache = inp
        h, new_cache = _dec_block(layer_params, h, memory, cfg,
                                  cache=layer_cache, window=window)
        return h, new_cache

    h, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
    logits = common.rmsnorm(params["final_norm"], h, cfg.norm_eps) @ \
        params["head"]["kernel"].astype(h.dtype)
    return logits, new_cache
