"""Top-k mixture-of-experts FFN with GShard-style capacity einsum dispatch.

Dispatch/combine are dense einsums over a [tokens, experts, capacity]
one-hot — the battle-tested TPU formulation (GShard/Switch): every shape
is static, GSPMD shards the expert dimension on the "model" mesh axis
(expert parallelism) and lowers the token->expert shuffle to all-to-all /
all-gather collectives.  Tokens are processed in fixed-size groups so the
dispatch tensor stays bounded regardless of global batch.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec


class MoEAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    router_z_loss: jnp.ndarray
    # fraction of routed (token, k) slots dropped by capacity limits
    drop_fraction: jnp.ndarray


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.e_total
    return {
        "router": ParamSpec((d, e), ("embed", "expert"), scale=0.02),
        "wi_gate": ParamSpec((e, d, f), ("expert", "embed", "ffn")),
        "wi_up": ParamSpec((e, d, f), ("expert", "embed", "ffn")),
        "wo": ParamSpec((e, f, d), ("expert", "ffn", "embed")),
    }


def _route(logits: jnp.ndarray, top_k: int, n_real: int = 0):
    """logits [n, E] -> (combine weights [n, E], mask [n, E]).

    ``n_real``: experts >= n_real are padding (never routed)."""
    n, e = logits.shape
    if n_real and n_real < e:
        pad = jnp.arange(e) >= n_real
        logits = jnp.where(pad[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)           # [n, k]
    mask = jnp.sum(jax.nn.one_hot(top_idx, e, dtype=logits.dtype), axis=1)
    # renormalise over the selected experts
    weights = probs * mask
    weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
    return weights, mask, probs


def moe_ffn(params, x: jnp.ndarray, cfg: ModelConfig, group_size: int = 2048):
    """x: [B, S, d] -> ([B, S, d], MoEAux)."""
    mcfg = cfg.moe
    e, k = mcfg.e_total, mcfg.top_k
    b, s, d = x.shape
    n = b * s
    g = min(group_size, n)
    n_groups = n // g
    assert n_groups * g == n, f"tokens {n} not divisible by group {g}"
    cap = int(math.ceil(g * k * mcfg.capacity_factor / mcfg.n_experts))
    cap = max(cap, k)

    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    weights, mask, probs = jax.vmap(
        lambda l: _route(l, k, mcfg.n_experts))(logits)

    # position of each token within its expert's capacity buffer
    pos = jnp.cumsum(mask, axis=1) * mask - 1.0                # [n, g, e]
    keep = (pos >= 0) & (pos < cap)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
    keep = keep.astype(x.dtype)
    dispatch = pos_oh * keep[..., None]                        # [n, g, e, cap]
    combine = dispatch * weights.astype(x.dtype)[..., None]

    # dispatch -> expert compute -> combine
    xin = jnp.einsum("ngec,ngd->necd", dispatch, xt)           # [n, e, cap, d]
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", xin,
                               params["wi_gate"].astype(x.dtype)))
    h = h * jnp.einsum("necd,edf->necf", xin, params["wi_up"].astype(x.dtype))
    xout = jnp.einsum("necf,efd->necd", h, params["wo"].astype(x.dtype))
    y = jnp.einsum("ngec,necd->ngd", combine, xout)

    # aux losses (Switch-style load balance + router z-loss)
    frac_tokens = jnp.mean(mask, axis=1)                       # [n, e]
    frac_probs = jnp.mean(probs, axis=1)
    lb = jnp.mean(jnp.sum(frac_tokens * frac_probs, -1)) * mcfg.n_experts
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(dispatch) / float(n * k)
    aux = MoEAux(load_balance_loss=lb.astype(jnp.float32),
                 router_z_loss=zl.astype(jnp.float32),
                 drop_fraction=dropped.astype(jnp.float32))
    return y.reshape(b, s, d), aux


def moe_ffn_gather(params, x: jnp.ndarray, cfg: ModelConfig,
                   group_size: int = 2048):
    """Gather/scatter dispatch variant (§Perf iteration).

    The GShard einsum dispatch multiplies by a [tokens, E, capacity]
    one-hot — ~2·k·cf·g·E·cap·d useless MACs per layer that dominate
    small-d_ff MoEs (granite: 88% of compiled FLOPs).  Here the same
    capacity-bounded routing is materialised as int32 slot indices and
    the dispatch/combine become gathers: identical semantics (same
    capacity drops), near-zero extra FLOPs.
    """
    mcfg = cfg.moe
    e, k = mcfg.e_total, mcfg.top_k
    b, s, d = x.shape
    n = b * s
    g = min(group_size, n)
    n_groups = n // g
    assert n_groups * g == n, f"tokens {n} not divisible by group {g}"
    cap = max(int(math.ceil(g * k * mcfg.capacity_factor / mcfg.n_experts)),
              k)

    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    weights, mask, probs = jax.vmap(
        lambda l: _route(l, k, mcfg.n_experts))(logits)

    pos = jnp.cumsum(mask, axis=1) * mask - 1.0                # [n, g, e]
    kept = (pos >= 0) & (pos < cap)
    pos_i = pos.astype(jnp.int32)

    # slot -> token index table, one scatter per group
    eg = jnp.arange(e, dtype=jnp.int32)
    flat_slot = jnp.where(kept, eg[None, None, :] * cap + pos_i, e * cap)

    def scatter_group(slots, toks):
        tbl = jnp.full((e * cap + 1,), 0, jnp.int32)
        val = jnp.zeros((e * cap + 1,), jnp.bool_)
        tbl = tbl.at[slots.reshape(-1)].set(
            jnp.broadcast_to(toks[:, None], slots.shape).reshape(-1),
            mode="drop")
        val = val.at[slots.reshape(-1)].set(True, mode="drop")
        return tbl[:-1], val[:-1]

    toks = jnp.arange(g, dtype=jnp.int32)
    tbl, valid = jax.vmap(lambda sl: scatter_group(sl, toks))(flat_slot)
    tbl = tbl.reshape(n_groups, e, cap)
    valid = valid.reshape(n_groups, e, cap)

    # dispatch = pure gather
    xin = jnp.take_along_axis(xt, tbl.reshape(n_groups, e * cap)[:, :, None],
                              axis=1).reshape(n_groups, e, cap, d)
    xin = xin * valid[..., None].astype(x.dtype)

    h = jax.nn.silu(jnp.einsum("necd,edf->necf", xin,
                               params["wi_gate"].astype(x.dtype)))
    h = h * jnp.einsum("necd,edf->necf", xin, params["wi_up"].astype(x.dtype))
    xout = jnp.einsum("necf,efd->necd", h, params["wo"].astype(x.dtype))

    # combine = gather per (token, selected expert)
    top_w, top_idx = jax.lax.top_k(weights, k)                 # [n, g, k]
    pos_k = jnp.take_along_axis(pos_i, top_idx, axis=2)        # [n, g, k]
    kept_k = jnp.take_along_axis(kept, top_idx, axis=2)
    flat = top_idx * cap + jnp.maximum(pos_k, 0)               # [n, g, k]
    gathered = jnp.take_along_axis(
        xout.reshape(n_groups, e * cap, d),
        flat.reshape(n_groups, g * k)[:, :, None], axis=1
    ).reshape(n_groups, g, k, d)
    y = jnp.sum(gathered * (top_w * kept_k.astype(top_w.dtype)
                            )[..., None].astype(x.dtype), axis=2)

    frac_tokens = jnp.mean(mask, axis=1)
    frac_probs = jnp.mean(probs, axis=1)
    lb = jnp.mean(jnp.sum(frac_tokens * frac_probs, -1)) * mcfg.n_experts
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(kept) / float(n * k)
    aux = MoEAux(load_balance_loss=lb.astype(jnp.float32),
                 router_z_loss=zl.astype(jnp.float32),
                 drop_fraction=dropped.astype(jnp.float32))
    return y.reshape(b, s, d), aux
