"""Parameter-spec system + shared layers (RMSNorm, RoPE, embeddings).

Every module exposes ``specs(cfg) -> pytree[ParamSpec]``; generic helpers
turn a spec tree into real params (``init_params``), abstract
ShapeDtypeStructs for the dry-run (``abstract_params``) or logical-axis
PartitionSpec inputs (``logical_axes``).  Keeping shapes/axes/initialisers
in one place is what lets ``launch/dryrun.py`` lower every architecture
without allocating a single real weight.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: Optional[float] = None            # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _initializer(spec: ParamSpec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape) * std).astype(dtype)
    # fan-in scaled normal
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[0], 1)
    if len(spec.shape) == 3:  # stacked experts: fan-in is dim 1
        fan_in = spec.shape[1]
    std = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(specs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_initializer(s, k, dtype)
                 for s, k in zip(leaves, keys, strict=True)]
    )


def abstract_params(specs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
    )


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = "layer"):
    """Prepend a stacking dimension (layer scan) to every spec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        spec_tree,
        is_leaf=_is_spec,
    )


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int):
    return {"scale": ParamSpec((d,), (None,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm(x, eps: float = 1e-6, scale=None, bias=None):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    angles = angles[..., None, :]                                 # broadcast heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_specs(vocab: int, d: int):
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), init="embed")}


def embed(params, tokens):
    return params["embedding"][tokens]


def unembed(params, x):
    return x @ params["embedding"].T.astype(x.dtype)


def dense_specs(d_in: int, d_out: int, in_ax: Optional[str], out_ax: Optional[str],
                use_bias: bool = False, scale: Optional[float] = None):
    s = {"kernel": ParamSpec((d_in, d_out), (in_ax, out_ax), scale=scale)}
    if use_bias:
        s["bias"] = ParamSpec((d_out,), (out_ax,), init="zeros")
    return s


def dense(params, x):
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y
