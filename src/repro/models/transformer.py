"""Causal language model: embed -> block stack -> norm -> head.

Also the VLM variant: precomputed vision-frontend patch embeddings (the
assignment's stub carve-out) are projected and prepended to the token
embeddings; loss is computed on text positions only.

``forward`` returns the Cumulative Residual Feature (CRF) next to the
logits — the final pre-norm hidden state, which per the paper equals the
input embedding plus the sum of every residual update.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, common
from repro.models.common import ParamSpec


class LMOutput(NamedTuple):
    logits: jnp.ndarray
    crf: jnp.ndarray
    aux: blocks.BlockAux


def lm_specs(cfg: ModelConfig):
    s: Dict[str, Any] = {
        "embed": common.embed_specs(cfg.vocab_size, cfg.d_model),
        "stack": blocks.stack_specs(cfg),
        "final_norm": common.rmsnorm_specs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["head"] = {"kernel": ParamSpec((cfg.d_model, cfg.vocab_size),
                                         ("embed", "vocab"), scale=0.02)}
    if cfg.n_prefix_tokens > 0:
        # projection of (stubbed) modality-frontend embeddings into d_model
        s["prefix_proj"] = common.dense_specs(cfg.d_model, cfg.d_model,
                                              "embed", None)
    return s


def _head(params, h, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return common.unembed(params["embed"], h)
    return h @ params["head"]["kernel"].astype(h.dtype)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None,
            window: int = 0, remat: Optional[bool] = None,
            constrain=None) -> LMOutput:
    """tokens: [B, S_text]; prefix_embeds: [B, P, d_model] or None."""
    x = common.embed(params["embed"], tokens)
    dtype = jnp.dtype(cfg.dtype)
    x = x.astype(dtype)
    if prefix_embeds is not None:
        pe = common.dense(params["prefix_proj"], prefix_embeds.astype(dtype))
        x = jnp.concatenate([pe, x], axis=1)
    h, aux = blocks.stack_full(params["stack"], x, cfg, window=window,
                               remat=remat, constrain=constrain)
    logits = _head(params, common.rmsnorm(params["final_norm"], h,
                                          cfg.norm_eps), cfg)
    return LMOutput(logits=logits, crf=h, aux=aux)


def _embedding_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T
    return params["head"]["kernel"]


def chunked_cross_entropy(params, h: jnp.ndarray, labels: jnp.ndarray,
                          cfg: ModelConfig, chunk: int = 512):
    """Sequence-chunked CE so [B, S, vocab] logits never materialise.

    h: final-normed hidden [B, S, d]; labels [B, S] with -1 = masked.
    The chunk body is rematerialised on backward (logits recomputed).
    """
    b, s, d = h.shape
    c = min(chunk, s)
    while s % c:          # largest divisor of s at most `chunk`
        c -= 1
    n = s // c
    hr = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)
    lr = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    w = _embedding_matrix(params, cfg)

    @jax.checkpoint
    def step(carry, inp):
        tot, cnt = carry
        hc, lc = inp
        logits = (hc @ w.astype(hc.dtype)).astype(jnp.float32)
        valid = lc >= 0
        lc = jnp.maximum(lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = logz - gold
        return (tot + jnp.sum(nll * valid), cnt + jnp.sum(valid)), ()

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (hr, lr))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            constrain=None, constrain_ffn=None, constrain_heads=None):
    """Next-token cross-entropy; label -1 positions are masked out."""
    x = common.embed(params["embed"], batch["tokens"])
    dtype = jnp.dtype(cfg.dtype)
    x = x.astype(dtype)
    if cfg.n_prefix_tokens > 0:
        pe = common.dense(params["prefix_proj"],
                          batch["prefix_embeds"].astype(dtype))
        x = jnp.concatenate([pe, x], axis=1)
    h, out_aux = blocks.stack_full(params["stack"], x, cfg,
                                   constrain=constrain,
                                   constrain_ffn=constrain_ffn,
                                   constrain_heads=constrain_heads)
    if cfg.n_prefix_tokens > 0:
        h = h[:, cfg.n_prefix_tokens:]
    hn = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    loss = chunked_cross_entropy(params, hn, batch["labels"], cfg)
    out = LMOutput(logits=None, crf=h, aux=out_aux)
    if cfg.moe is not None:
        loss = (loss + cfg.moe.aux_loss_weight * out.aux.load_balance_loss
                + cfg.moe.router_z_weight * out.aux.router_z_loss)
    metrics = {"loss": loss, "lb_loss": out.aux.load_balance_loss,
               "drop_fraction": out.aux.drop_fraction}
    return loss, metrics


def decode_step(params, tokens: jnp.ndarray, cache, cfg: ModelConfig,
                window: int = 0):
    """tokens: [B, 1] -> (logits [B, 1, V], new_cache)."""
    x = common.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    h, new_cache, _ = blocks.stack_decode(params["stack"], x, cfg, cache,
                                          window=window)
    logits = _head(params, common.rmsnorm(params["final_norm"], h,
                                          cfg.norm_eps), cfg)
    return logits, new_cache
