"""Procedural synthetic datasets (no external data offline).

* ``shapes_batch`` — anti-aliased random ellipses/rectangles/stripes
  rendered into [B, H, W, C] "latents"; class-conditional structure so a
  small DiT has something real to learn (low-frequency layout + sharp
  high-frequency edges — exactly the band structure FreqCa exploits).
* ``lm_batch`` — a deterministic mixture of Markov token streams for the
  LM training examples.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def shapes_batch(rng: jax.Array, batch: int, size: int = 32,
                 channels: int = 4) -> jnp.ndarray:
    """Render random soft shapes. Returns [B, size, size, C] in ~[-1, 1]."""
    keys = jax.random.split(rng, 6)
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, size), jnp.linspace(-1, 1, size),
                          indexing="ij")
    cx = jax.random.uniform(keys[0], (batch, 1, 1), minval=-0.5, maxval=0.5)
    cy = jax.random.uniform(keys[1], (batch, 1, 1), minval=-0.5, maxval=0.5)
    rx = jax.random.uniform(keys[2], (batch, 1, 1), minval=0.2, maxval=0.6)
    ry = jax.random.uniform(keys[3], (batch, 1, 1), minval=0.2, maxval=0.6)
    kind = jax.random.randint(keys[4], (batch, 1, 1), 0, 3)
    phase = jax.random.uniform(keys[5], (batch, 1, 1), minval=0, maxval=np.pi)

    d_ell = ((xx - cx) / rx) ** 2 + ((yy - cy) / ry) ** 2
    ellipse = jax.nn.sigmoid((1.0 - d_ell) * 12.0)
    d_rect = jnp.maximum(jnp.abs(xx - cx) / rx, jnp.abs(yy - cy) / ry)
    rect = jax.nn.sigmoid((1.0 - d_rect) * 16.0)
    stripes = 0.5 + 0.5 * jnp.sin(8.0 * (xx * jnp.cos(phase)
                                         + yy * jnp.sin(phase)))
    img = jnp.where(kind == 0, ellipse, jnp.where(kind == 1, rect, stripes))
    img = img * 2.0 - 1.0                                  # [-1, 1]
    chans = [img]
    for c in range(1, channels):
        chans.append(jnp.roll(img, shift=c * 2, axis=-1) * (0.5 ** c))
    return jnp.stack(chans, axis=-1)


def lm_batch(rng: jax.Array, batch: int, seq_len: int,
             vocab: int) -> Dict[str, jnp.ndarray]:
    """Markov-chain token stream; labels are next tokens."""
    k1, k2 = jax.random.split(rng)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    steps = jax.random.randint(k2, (batch, seq_len), 1, 7)

    def scan_fn(tok, step):
        nxt = (tok * 31 + step) % vocab
        return nxt, nxt

    _, toks = jax.lax.scan(
        lambda c, s: scan_fn(c, s), start[:, 0], steps.T)
    tokens = toks.T
    labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((batch, 1), jnp.int32)],
                             axis=1)
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


def data_iterator(kind: str, batch: int, seed: int = 0, **kw):
    """Infinite host-side iterator of device-ready batches."""
    i = 0
    while True:
        rng = jax.random.key(seed * 100003 + i)
        if kind == "shapes":
            yield {"latents": shapes_batch(rng, batch, **kw)}
        else:
            yield lm_batch(rng, batch, **kw)
        i += 1
