"""Hermite-polynomial trajectory predictor (paper §3.2, strategy 2).

Each high-frequency coefficient is modelled as
``h_i(s) = sum_k c_{i,k} He_k(s)`` on normalised time ``s in [-1, 1]``,
with coefficients fitted by least squares over the K most recent
*activated* steps.  With K == m+1 sample points the fit is exact
interpolation (He_0..He_m span polynomials of degree m), so the
predictor reproduces any degree-<=m polynomial trajectory exactly —
property-tested in tests/test_core_freqca.py.

The solve is a single (m+1)x(m+1) normal-equation system shared by *all*
features (the basis depends only on the timestamps), so prediction is a
tiny matmul over the stacked history — O(K·numel) FLOPs, negligible next
to a transformer forward (paper: C_pred << C_full).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hermite_basis(s: jnp.ndarray, order: int) -> jnp.ndarray:
    """Probabilists' Hermite polynomials He_0..He_order at s. -> [..., order+1].

    He_0 = 1, He_1 = s, He_{k+1} = s·He_k − k·He_{k−1}.
    """
    s = s.astype(jnp.float32)
    cols = [jnp.ones_like(s)]
    if order >= 1:
        cols.append(s)
    for k in range(1, order):
        cols.append(s * cols[-1] - k * cols[-2])
    return jnp.stack(cols, axis=-1)


def normalize_times(ts: jnp.ndarray, t_query) -> jnp.ndarray:
    """Map times so the cached history spans [-1, 0] and extrapolation
    targets land just beyond — keeps the basis well-conditioned."""
    ts = ts.astype(jnp.float32)
    lo, hi = jnp.min(ts), jnp.max(ts)
    span = jnp.maximum(hi - lo, 1e-6)
    return (jnp.asarray(t_query, jnp.float32) - hi) / span


def normal_system(ts: jnp.ndarray, order: int):
    """Shared normal-equation setup for the least-squares Hermite fit.

    Returns ``(basis [K, m+1], g [m+1, m+1])`` with Tikhonov jitter for
    K > m+1 robustness — the single source used by ``fit_coefficients``,
    ``predict``, and ``eval_weights`` (they must agree bit-for-bit so
    the folded-weights kernel path matches the explicit fit).
    """
    s = normalize_times(ts, ts)                       # [K] in [-1, 0]
    basis = hermite_basis(s, order)                   # [K, m+1]
    g = basis.T @ basis + 1e-6 * jnp.eye(order + 1, dtype=jnp.float32)
    return basis, g


def fit_coefficients(ts: jnp.ndarray, values: jnp.ndarray, order: int):
    """Least-squares Hermite fit.

    ts: [K] timestamps of the cached history (diffusion step times);
    values: [K, ...] feature history.  Returns coeffs [order+1, ...].
    """
    basis, g = normal_system(ts, order)
    # shapes are kept intact (no reshape(k, -1)!) so sharded feature
    # dims survive — a flatten here turns into a full all-gather of the
    # cache under GSPMD.  The solve is moveaxis-only for the same
    # reason: a transpose keeps the sharding, a reshape would not.
    rhs = jnp.einsum("km,k...->m...", basis, values.astype(jnp.float32))
    if rhs.ndim == 1:
        return jnp.linalg.solve(g, rhs)
    coeffs = jnp.linalg.solve(g, jnp.moveaxis(rhs, 0, -2))
    return jnp.moveaxis(coeffs, -2, 0)


def eval_weights(ts: jnp.ndarray, t_query, order: int) -> jnp.ndarray:
    """Per-history scalar weights w st. prediction = sum_k w_k · hist_k.

    Solving the normal equations G c = B^T v and evaluating b_q^T c is
    linear in v, so the whole predictor folds into K scalars
    w = B G^{-1} b_q — the host-side half of the fused cached-step
    kernel (repro.kernels.freqca_fused).
    """
    basis, g = normal_system(ts, order)
    s_q = normalize_times(ts, t_query)
    basis_q = hermite_basis(s_q, order)               # [m+1]
    return basis @ jnp.linalg.solve(g, basis_q)       # [K]


def predict(ts: jnp.ndarray, values: jnp.ndarray, t_query, order: int):
    """Fit on (ts, values) history and evaluate at t_query. -> values[0]-like.

    Implemented via the folded weights (``eval_weights``) — the
    prediction is linear in the cached history.
    """
    w = eval_weights(ts, t_query, order)
    out = jnp.einsum("k,k...->...", w, values.astype(jnp.float32))
    return out.astype(values.dtype)


def predict_from_coeffs(coeffs: jnp.ndarray, ts: jnp.ndarray, t_query,
                        order: int):
    s_q = normalize_times(ts, t_query)
    basis_q = hermite_basis(s_q, order)
    return jnp.einsum("m,m...->...", basis_q, coeffs.astype(jnp.float32))
