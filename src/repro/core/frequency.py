"""Frequency decomposition of cached features (paper §3.2, eq. 1).

``z = z_low + z_high`` where the bands come from a generic transform
``D`` — FFT or DCT-II along the *token* axis — and complementary
projection operators P_low / P_high (an ideal low-pass mask keeping a
fraction ``rho`` of the spectrum).  Both transforms are orthogonal (up to
our normalisation), so the split is exactly a partition:
``decompose`` then summing the bands reconstructs the input to float
round-off (property-tested).

TPU note (DESIGN.md §3): DCT-II is implemented as a dense basis matmul —
MXU-native — with a Pallas kernel in ``repro.kernels.dct``; this module
is the pure-jnp reference path used everywhere correctness matters.
"""
from __future__ import annotations

import functools
import math
from typing import Literal, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Method = Literal["fft", "dct", "none"]


class Bands(NamedTuple):
    low: jnp.ndarray
    high: jnp.ndarray


@functools.lru_cache(maxsize=16)
def _dct_basis_np(n: int) -> np.ndarray:
    """Orthonormal DCT-II basis C with C @ C.T = I; rows = frequencies."""
    k = np.arange(n)[:, None].astype(np.float64)
    i = np.arange(n)[None, :].astype(np.float64)
    basis = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * math.sqrt(2.0 / n)
    basis[0] *= 1.0 / math.sqrt(2.0)
    return basis


def dct_basis(n: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_dct_basis_np(n), dtype)


def dct(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Orthonormal DCT-II along ``axis``."""
    n = x.shape[axis]
    c = dct_basis(n, jnp.float32)
    xm = jnp.moveaxis(x, axis, -1).astype(jnp.float32)
    return jnp.moveaxis(xm @ c.T, -1, axis).astype(x.dtype)


def idct(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    n = x.shape[axis]
    c = dct_basis(n, jnp.float32)
    xm = jnp.moveaxis(x, axis, -1).astype(jnp.float32)
    return jnp.moveaxis(xm @ c, -1, axis).astype(x.dtype)


def low_pass_mask_np(n: int, rho: float, method: Method) -> np.ndarray:
    """Boolean mask over the n frequency bins; True = low-frequency.

    Single source of truth for the band split (the jnp ``low_pass_mask``
    and the kernels' host-side projection bases all derive from it).
    Both transforms target ``m = round(n * rho)`` (clamped to [1, n])
    kept bins.  The DCT spectrum is one-sided: low = [0, m), exactly
    ``m`` bins.  The real-signal FFT projection must be
    conjugate-symmetric — DC plus whole ±frequency pairs, an odd count,
    living at both ends of the bin axis — so an even target rounds *up*
    to ``m + 1`` kept bins (``k = m // 2`` pairs; never narrower than
    the DCT band for the same ``rho``): the two methods always
    decompose the same band within one bin.
    """
    m = min(max(int(round(n * rho)), 1), n)
    idx = np.arange(n)
    if method == "fft":
        # conjugate-symmetric, so the real-signal projection is
        # orthogonal (Parseval holds)
        k = m // 2
        return (idx <= k) | (idx >= n - k)
    return idx < m


def kept_bins(n: int, rho: float, method: Method) -> int:
    """Number of low-frequency bins ``low_pass_mask`` keeps."""
    return int(low_pass_mask_np(n, rho, method).sum())


def low_pass_mask(n: int, rho: float, method: Method) -> jnp.ndarray:
    return jnp.asarray(low_pass_mask_np(n, rho, method))


def decompose(z: jnp.ndarray, rho: float, method: Method,
              axis: int = -2) -> Bands:
    """Split features into complementary low/high bands (paper eq. 1).

    z: [..., S, D] (token axis = ``axis``).  ``rho`` is the fraction of
    the spectrum treated as low-frequency.  Returns *spatial-domain*
    bands with ``low + high == z``.
    """
    if method == "none":
        return Bands(low=jnp.zeros_like(z), high=z)
    n = z.shape[axis]
    mask = low_pass_mask(n, rho, method)
    shape = [1] * z.ndim
    shape[axis] = n
    mask = mask.reshape(shape)
    if method == "fft":
        zf = jnp.fft.fft(z.astype(jnp.float32), axis=axis)
        low = jnp.fft.ifft(jnp.where(mask, zf, 0.0), axis=axis).real
        low = low.astype(z.dtype)
        return Bands(low=low, high=z - low)
    if method == "dct":
        zf = dct(z.astype(jnp.float32), axis=axis)
        low = idct(jnp.where(mask, zf, 0.0), axis=axis).astype(z.dtype)
        return Bands(low=low, high=z - low)
    raise ValueError(method)


def band_energies(z: jnp.ndarray, rho: float, method: Method,
                  axis: int = -2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = decompose(z, rho, method, axis)
    f32 = jnp.float32
    return (jnp.sum(jnp.square(b.low.astype(f32))),
            jnp.sum(jnp.square(b.high.astype(f32))))


def cosine_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32).ravel()
    bf = b.astype(jnp.float32).ravel()
    return jnp.vdot(af, bf) / jnp.maximum(
        jnp.linalg.norm(af) * jnp.linalg.norm(bf), 1e-12)
