"""Frequency decomposition of cached features (paper §3.2, eq. 1).

``z = z_low + z_high`` where the bands come from a generic transform
``D`` — FFT or DCT-II along the *token* axis — and complementary
projection operators P_low / P_high (an ideal low-pass mask keeping a
fraction ``rho`` of the spectrum).  Both transforms are orthogonal (up to
our normalisation), so the split is exactly a partition:
``decompose`` then summing the bands reconstructs the input to float
round-off (property-tested).

TPU note (DESIGN.md §3): DCT-II is implemented as a dense basis matmul —
MXU-native — with a Pallas kernel in ``repro.kernels.dct``; this module
is the pure-jnp reference path used everywhere correctness matters.
"""
from __future__ import annotations

import functools
import math
from typing import Literal, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Method = Literal["fft", "dct", "none"]


class Bands(NamedTuple):
    low: jnp.ndarray
    high: jnp.ndarray


@functools.lru_cache(maxsize=None)
def _dct_basis_np(n: int) -> np.ndarray:
    """Orthonormal DCT-II basis C with C @ C.T = I; rows = frequencies."""
    k = np.arange(n)[:, None].astype(np.float64)
    i = np.arange(n)[None, :].astype(np.float64)
    basis = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * math.sqrt(2.0 / n)
    basis[0] *= 1.0 / math.sqrt(2.0)
    return basis


def dct_basis(n: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_dct_basis_np(n), dtype)


def dct(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Orthonormal DCT-II along ``axis``."""
    n = x.shape[axis]
    c = dct_basis(n, jnp.float32)
    xm = jnp.moveaxis(x, axis, -1).astype(jnp.float32)
    return jnp.moveaxis(xm @ c.T, -1, axis).astype(x.dtype)


def idct(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    n = x.shape[axis]
    c = dct_basis(n, jnp.float32)
    xm = jnp.moveaxis(x, axis, -1).astype(jnp.float32)
    return jnp.moveaxis(xm @ c, -1, axis).astype(x.dtype)


def low_pass_mask_np(n: int, rho: float, method: Method) -> np.ndarray:
    """Boolean mask over the n frequency bins; True = low-frequency.

    Single source of truth for the band split (the jnp ``low_pass_mask``
    and the kernels' host-side projection bases all derive from it).
    Both transforms target ``m = round(n * rho)`` (clamped to [1, n])
    kept bins.  The DCT spectrum is one-sided: low = [0, m), exactly
    ``m`` bins.  The real-signal FFT projection must be
    conjugate-symmetric — DC plus whole ±frequency pairs, an odd count,
    living at both ends of the bin axis — so an even target rounds *up*
    to ``m + 1`` kept bins (``k = m // 2`` pairs; never narrower than
    the DCT band for the same ``rho``): the two methods always
    decompose the same band within one bin.
    """
    m = min(max(int(round(n * rho)), 1), n)
    idx = np.arange(n)
    if method == "fft":
        # conjugate-symmetric, so the real-signal projection is
        # orthogonal (Parseval holds)
        k = m // 2
        return (idx <= k) | (idx >= n - k)
    return idx < m


def kept_bins(n: int, rho: float, method: Method) -> int:
    """Number of low-frequency bins ``low_pass_mask`` keeps."""
    return int(low_pass_mask_np(n, rho, method).sum())


def low_pass_mask(n: int, rho: float, method: Method) -> jnp.ndarray:
    return jnp.asarray(low_pass_mask_np(n, rho, method))


def spectral_kept_bins(n: int, rho: float, method: Method) -> int:
    """Rows of ``low_band_basis`` — the spectral low-ring width.

    ``method="none"`` has an empty low band; a single all-zero basis row
    keeps the cache state shapes static (the coefficients are exactly
    zero, so reconstruction is unaffected).
    """
    if method == "none":
        return 1
    return kept_bins(n, rho, method)


# unbounded: a bounded cache (maxsize=16) silently evicted once more
# than 16 (n, rho, method) combos were live — exactly the
# multi-resolution serving regime — forcing repeated O(n^2) basis
# rebuilds on the hot path.  The bases are tiny (m x n float64), so
# keeping every combo for the process lifetime is the right trade.
@functools.lru_cache(maxsize=None)
def _low_band_basis_np(n: int, rho: float, method: Method) -> np.ndarray:
    """Real orthonormal basis ``B: [m, n]`` spanning the low band.

    The spatial low-pass projection factorises as ``L = Bᵀ B``: analysis
    ``c = B x`` keeps only ``m = spectral_kept_bins(n, rho, method)``
    spectral rows (the compressed cache representation — SpectralCache,
    arXiv 2603.05315), synthesis ``Bᵀ c`` reconstructs the spatial low
    band.  DCT: the first m rows of the orthonormal DCT-II basis.  FFT:
    the real Fourier basis for the conjugate-symmetric kept set — DC,
    then (cos, sin) row pairs per kept ±frequency pair (a lone
    normalised cos row at Nyquist) — which spans exactly the same
    subspace as the complex mask projection.
    """
    if method == "none":
        return np.zeros((1, n), np.float64)
    if method == "dct":
        m = kept_bins(n, rho, method)
        return _dct_basis_np(n)[:m]
    assert method == "fft", method
    mask = low_pass_mask_np(n, rho, "fft")
    k = int(mask[1:(n // 2) + 1].sum())      # kept positive frequencies
    i = np.arange(n, dtype=np.float64)
    rows = [np.full(n, 1.0 / math.sqrt(n))]
    for f in range(1, k + 1):
        ang = 2.0 * np.pi * f * i / n
        if 2 * f == n:                       # Nyquist: lone real mode
            rows.append(np.cos(ang) / math.sqrt(n))
        else:
            rows.append(np.cos(ang) * math.sqrt(2.0 / n))
            rows.append(np.sin(ang) * math.sqrt(2.0 / n))
    basis = np.stack(rows)
    assert basis.shape[0] == kept_bins(n, rho, "fft"), basis.shape
    return basis


def low_band_basis(n: int, rho: float, method: Method,
                   dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_low_band_basis_np(n, rho, method), dtype)


def _kernel_dispatch_ok(z: jnp.ndarray, axis: int) -> bool:
    """True when the Pallas band-split kernel can take this call: the
    [B, S, D] token-axis layout with tile-compatible S and D."""
    if z.ndim != 3 or axis not in (-2, 1):
        return False
    from repro.kernels import dct as dct_kernel  # lazy: dct imports us
    return dct_kernel.band_split_dispatch_ok(z.shape[-2], z.shape[-1])


def decompose(z: jnp.ndarray, rho: float, method: Method,
              axis: int = -2) -> Bands:
    """Split features into complementary low/high bands (paper eq. 1).

    z: [..., S, D] (token axis = ``axis``).  ``rho`` is the fraction of
    the spectrum treated as low-frequency.  Returns *spatial-domain*
    bands with ``low + high == z``.
    """
    if method == "none":
        return Bands(low=jnp.zeros_like(z), high=z)
    if _kernel_dispatch_ok(z, axis):
        # kernel-backed band split (REPRO_KERNELS=pallas): one fused
        # projection matmul instead of the transform round-trip.  The
        # pure path below stays the oracle the kernels are tested
        # against (the dispatch layer only routes here when it is off).
        from repro.kernels import ops
        if ops.use_pallas():
            low, high = ops.band_split(z, rho, method)
            return Bands(low=low, high=high)
    n = z.shape[axis]
    mask = low_pass_mask(n, rho, method)
    shape = [1] * z.ndim
    shape[axis] = n
    mask = mask.reshape(shape)
    if method == "fft":
        zf = jnp.fft.fft(z.astype(jnp.float32), axis=axis)
        low = jnp.fft.ifft(jnp.where(mask, zf, 0.0), axis=axis).real
        low = low.astype(z.dtype)
        return Bands(low=low, high=z - low)
    if method == "dct":
        zf = dct(z.astype(jnp.float32), axis=axis)
        low = idct(jnp.where(mask, zf, 0.0), axis=axis).astype(z.dtype)
        return Bands(low=low, high=z - low)
    raise ValueError(method)


def band_energies(z: jnp.ndarray, rho: float, method: Method,
                  axis: int = -2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = decompose(z, rho, method, axis)
    f32 = jnp.float32
    return (jnp.sum(jnp.square(b.low.astype(f32))),
            jnp.sum(jnp.square(b.high.astype(f32))))


def cosine_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32).ravel()
    bf = b.astype(jnp.float32).ravel()
    return jnp.vdot(af, bf) / jnp.maximum(
        jnp.linalg.norm(af) * jnp.linalg.norm(bf), 1e-12)
