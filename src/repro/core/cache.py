"""Legacy feature-cache API: the ``CachePolicy`` spec + function-style
state machines.

The sampler now drives self-contained policy *objects* registered in
``repro.core.policies`` (per-lane activation masks, policy-owned
adaptive state).  ``CachePolicy`` remains the user-facing spec — a thin
compat shim whose ``.resolve()`` returns the registered policy object
for its ``kind`` — and the function-style API below (``init_state`` /
``should_activate`` / ``update`` / ``predict``) is kept for the
layer-wise Table-5/Fig-4 ablations, the roofline step specs, and the
golden-equivalence tests that pin the new objects against it.

Policies (``kind``):
  freqca      — paper: low band reused (order ``low_order``, default 0),
                high band Hermite-predicted (order ``high_order``, default
                2), bands split by ``method`` (fft | dct) at fraction
                ``rho``.  Cache = (low_order+1) + (high_order+1) feature
                tensors — O(1) in depth (CRF caching).
  taylorseer  — whole-feature polynomial forecast of order ``high_order``
                (no decomposition) == the paper's main forecast baseline.
  fora        — whole-feature reuse (order 0) == the paper's main reuse
                baseline.
  teacache    — TeaCache-style ADAPTIVE reuse: the sampler accumulates
                the relative change of the model input x_t between
                steps and triggers a full forward when it crosses
                ``tea_threshold`` (the interval schedule is ignored);
                prediction = reuse, like FORA.
  foca        — forecast-then-calibrate (arXiv 2508.16211): in this
                legacy API it degrades to the taylorseer forecast; the
                registry object carries the per-lane calibration gain.
  freqca_a    — beyond-paper ADAPTIVE FreqCa: at every activated step
                the cache state already contains what FreqCa *would
                have predicted* for that step — its relative error
                against the freshly computed CRF is free to measure.
                The sampler then budgets cached steps from it:
                skip while (steps_since_full+1) · err_last <
                ``tea_threshold``; bands/predictors identical to
                freqca.  Unifies TeaCache's adaptivity with FreqCa's
                frequency-split predictor.
  none        — never cache (ground truth / baseline latency).

``should_activate`` implements the paper's schedule: a full forward every
``interval`` steps, plus a warm-up of full steps until the history is
populated.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import frequency, hermite


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    kind: str = "freqca"          # freqca | taylorseer | fora | none
    interval: int = 5             # N: full forward every N steps
    method: str = "dct"           # fft | dct | none (frequency transform)
    rho: float = 0.0625           # low-frequency fraction of the spectrum
    low_order: int = 0            # 0 = direct reuse (paper default)
    high_order: int = 2           # Hermite order for the high band
    token_axis: int = 1           # axis of [B, S, D] to transform over
    tea_threshold: float = 0.15   # teacache / freqca_a error budget

    @property
    def k_low(self) -> int:
        return self.low_order + 1

    @property
    def k_high(self) -> int:
        return self.high_order + 1

    @property
    def cache_units(self) -> int:
        """Number of feature-sized tensors held (paper §4.4.1)."""
        if self.kind == "none":
            return 0
        if self.kind in ("fora", "teacache"):
            return 1
        if self.kind in ("taylorseer", "foca"):
            return self.k_high
        return self.k_low + self.k_high   # freqca / freqca_a

    def resolve(self):
        """Registered policy object for this spec (repro.core.policies).

        .. deprecated:: construct the policy object directly
           (``FreqCaPolicy(interval=5)``); the string-kind spec route
           is kept only as a shim and warns once per process.
        """
        global _RESOLVE_WARNED
        if not _RESOLVE_WARNED:
            _RESOLVE_WARNED = True
            import warnings
            warnings.warn(
                "CachePolicy.resolve() is deprecated; construct policy "
                "objects from repro.core.policies directly "
                "(e.g. FreqCaPolicy(interval=5))",
                DeprecationWarning, stacklevel=2)
        from repro.core.policies import registry
        return registry.resolve(self)


_RESOLVE_WARNED = False


class CacheState(NamedTuple):
    low_hist: jnp.ndarray     # [K_low,  *feat] spatial-domain low band
    high_hist: jnp.ndarray    # [K_high, *feat] spatial-domain high band
    ts_low: jnp.ndarray       # [K_low]
    ts_high: jnp.ndarray      # [K_high]
    n_valid: jnp.ndarray      # [] int32 — activated steps seen so far


def init_state(policy: CachePolicy, feat_shape: Tuple[int, ...],
               dtype=jnp.float32) -> CacheState:
    kl, kh = policy.k_low, policy.k_high
    if policy.kind in ("fora", "teacache"):
        kl, kh = 1, 1
    if policy.kind in ("taylorseer", "foca", "none"):
        kl = 1  # unused slot kept tiny-but-static
    return CacheState(
        low_hist=jnp.zeros((kl,) + tuple(feat_shape), dtype),
        high_hist=jnp.zeros((kh,) + tuple(feat_shape), dtype),
        ts_low=jnp.full((kl,), -1.0, jnp.float32),
        ts_high=jnp.full((kh,), -1.0, jnp.float32),
        n_valid=jnp.zeros((), jnp.int32),
    )


def _needed_history(policy: CachePolicy) -> int:
    if policy.kind in ("fora", "teacache"):
        return 1
    if policy.kind in ("taylorseer", "foca"):
        return policy.k_high
    if policy.kind in ("freqca", "freqca_a"):
        return max(policy.k_low, policy.k_high)
    return 1


def should_activate(policy: CachePolicy, state: CacheState,
                    step_idx: jnp.ndarray) -> jnp.ndarray:
    if policy.kind == "none":
        return jnp.asarray(True)
    scheduled = (step_idx % policy.interval) == 0
    warmup = state.n_valid < _needed_history(policy)
    return scheduled | warmup


def _push(hist, ts, value, t):
    hist = jnp.roll(hist, -1, axis=0).at[-1].set(value.astype(hist.dtype))
    ts = jnp.roll(ts, -1).at[-1].set(jnp.asarray(t, jnp.float32))
    return hist, ts


def update(policy: CachePolicy, state: CacheState, z: jnp.ndarray,
           t) -> CacheState:
    """Push the freshly computed CRF ``z`` (activated step at time t)."""
    if policy.kind == "none":
        return state
    if policy.kind in ("fora", "taylorseer", "foca", "teacache"):
        low, high = jnp.zeros_like(z), z
    else:  # freqca / freqca_a
        bands = frequency.decompose(z, policy.rho, policy.method,
                                    axis=policy.token_axis)
        low, high = bands.low, bands.high
    low_hist, ts_low = _push(state.low_hist, state.ts_low, low, t)
    high_hist, ts_high = _push(state.high_hist, state.ts_high, high, t)
    return CacheState(low_hist=low_hist, high_hist=high_hist,
                      ts_low=ts_low, ts_high=ts_high,
                      n_valid=state.n_valid + 1)


def predict(policy: CachePolicy, state: CacheState, t) -> jnp.ndarray:
    """Reconstruct ẑ_t from the cache (cached step at time t)."""
    if policy.kind in ("fora", "teacache"):
        return state.high_hist[-1]
    if policy.kind in ("taylorseer", "foca"):
        # legacy path has no per-lane gain state: foca degrades to the
        # uncalibrated forecast (the registry object is the real thing)
        return hermite.predict(state.ts_high, state.high_hist, t,
                               policy.high_order)
    assert policy.kind in ("freqca", "freqca_a"), policy.kind
    if policy.low_order == 0:
        low = state.low_hist[-1]
    else:
        low = hermite.predict(state.ts_low, state.low_hist, t,
                              policy.low_order)
    if policy.high_order == 0:
        high = state.high_hist[-1]
    else:
        high = hermite.predict(state.ts_high, state.high_hist, t,
                               policy.high_order)
    return low + high


def cache_bytes(state: CacheState, policy: CachePolicy = None) -> int:
    """Bytes the policy actually caches.

    ``init_state`` keeps a tiny-but-static dummy ``low_hist`` slot for
    the kinds that never decompose (``update`` pushes zeros into it), so
    a plain pytree sum over-reports those policies.  Pass ``policy`` to
    exclude the dummy slots (Table-5 memory accounting); without it the
    raw pytree size is returned (allocation footprint).
    """
    total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
    if policy is None:
        return total
    if policy.kind == "none":
        return 0
    if policy.kind in ("fora", "taylorseer", "foca", "teacache"):
        return total - (state.low_hist.size * state.low_hist.dtype.itemsize
                        + state.ts_low.size * state.ts_low.dtype.itemsize)
    return total


# ---------------------------------------------------------------------------
# layer-wise variant (paper Fig. 4 / Table 5 ablation)
# ---------------------------------------------------------------------------

class LayerwiseState(NamedTuple):
    """Caches every layer's residual delta — the O(L) baseline."""
    hist: jnp.ndarray        # [K, L, *feat]
    ts: jnp.ndarray          # [K]
    n_valid: jnp.ndarray


def layerwise_init(policy: CachePolicy, n_layers: int,
                   feat_shape: Tuple[int, ...], dtype=jnp.float32):
    k = policy.k_high
    return LayerwiseState(
        hist=jnp.zeros((k, n_layers) + tuple(feat_shape), dtype),
        ts=jnp.full((k,), -1.0, jnp.float32),
        n_valid=jnp.zeros((), jnp.int32),
    )


def layerwise_update(policy: CachePolicy, state: LayerwiseState,
                     residuals: jnp.ndarray, t) -> LayerwiseState:
    hist, ts = _push(state.hist, state.ts, residuals, t)
    return LayerwiseState(hist=hist, ts=ts, n_valid=state.n_valid + 1)


def layerwise_predict(policy: CachePolicy, state: LayerwiseState, t,
                      h0: jnp.ndarray) -> jnp.ndarray:
    """Predict each layer residual, reconstruct CRF = h0 + sum_l F̂^l."""
    res = hermite.predict(state.ts, state.hist, t, policy.high_order)
    return h0 + jnp.sum(res, axis=0)
