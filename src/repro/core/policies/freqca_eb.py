"""FreqCa-EB (beyond paper): error-budgeted, feedback-driven activation.

FreqCa's spectral split makes per-band prediction error cheap to
measure: on every full step the low ring already holds the coefficients
the lane would have served, so scoring them against the fresh
``_split`` output costs one subtraction in the spectral basis — the
low band is never synthesized back to the spatial domain.  Following
SpectralCache's error-bounded activation (arXiv 2603.05315) and
error-feedback event-driven caching (arXiv 2604.22901), the measured
per-band error rate is carried forward as policy state and *spent*
against a budget:

* each cached step spends ``rate = rate_low + rate_high`` from the
  accumulator (``acc``) — the projected error the lane commits by
  serving the prediction;
* a full forward fires as an **event** exactly when the next cached
  step would overspend (``acc + rate > budget``), resetting ``acc``;
* the full step re-measures both band rates (``observe``), closing the
  feedback loop.

The budget is a per-request quality SLO: ``with_budget(max_error)``
snaps the request's ``max_error`` down to a tier from ``ERROR_TIERS``
so jit signatures and scheduler compatibility groups stay bounded —
the tier is a dataclass field, so it folds into ``compatibility_key``
(adaptive policies key on their full value) automatically.

By construction the accumulated error between two consecutive full
forwards never exceeds the budget, and the peak accumulator value is
reported per lane through ``error_feedback`` as the realized SLO.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry
from repro.core.policies.freqca import FreqCaPolicy

# Budget quantization ladder: requested max_error snaps DOWN to the
# nearest tier (never promising less quality than asked), so at most
# len(ERROR_TIERS) compiled signatures / compatibility groups exist.
ERROR_TIERS: Tuple[float, ...] = (0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0)


def budget_tier(max_error: float) -> float:
    """Largest tier <= max_error (strictest tier when below them all)."""
    eligible = [t for t in ERROR_TIERS if t <= max_error + 1e-12]
    return eligible[-1] if eligible else ERROR_TIERS[0]


class FreqCaEbState(NamedTuple):
    low: base.Ring                 # [B, K_low,  *feat|m] SPECTRAL low band
    high: base.Ring                # [B, K_high, *feat] spatial high band
    n_valid: jnp.ndarray           # [B] int32 — activated steps per lane
    rate_low: jnp.ndarray          # [B] f32 — low-band error rate
    rate_high: jnp.ndarray         # [B] f32 — high-band error rate
    acc: jnp.ndarray               # [B] f32 — error spent since last full
    peak: jnp.ndarray              # [B] f32 — max inter-full spend (SLO)
    events: jnp.ndarray            # [B] int32 — budget-triggered fulls


@dataclasses.dataclass(frozen=True)
class FreqCaErrorBudgetPolicy(FreqCaPolicy):
    name = "freqca_eb"
    per_lane = True
    uses_error_feedback = True

    budget: float = 0.1            # max error accumulated between fulls

    def with_budget(self, max_error: Optional[float]) -> "FreqCaPolicy":
        if max_error is None:
            return self
        return dataclasses.replace(self, budget=budget_tier(max_error))

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        zf = jnp.zeros((batch,), jnp.float32)
        return FreqCaEbState(
            low=base.ring_init(batch, self.k_low,
                               self.low_feat_shape(feat_shape), crf_dtype),
            high=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32),
            rate_low=zf, rate_high=zf, acc=zf, peak=zf,
            events=jnp.zeros((batch,), jnp.int32))

    def decide(self, state, ctx):
        # +1: one calibration full past the predictor's warm-up, so the
        # first adaptive skip is backed by a trusted measurement (the
        # rings only hold needed_history entries at the last warm-up
        # full, making that step's measurement meaningless)
        warm = state.n_valid < self.needed_history + 1
        rate = state.rate_low + state.rate_high
        spend = state.acc + rate
        act = warm | (spend > self.budget)
        # the sampler commits to this mask, so the budget bookkeeping
        # lands here: a cached lane spends (carry-over), an activated
        # lane resets its accumulator (reset on full step)
        acc = jnp.where(act, 0.0, spend)
        return state._replace(
            acc=acc,
            peak=jnp.maximum(state.peak, acc),
            events=state.events + (act & ~warm).astype(jnp.int32)), act

    def measure_error(self, state, crf, ctx):
        """Per-band prediction error vs the fresh CRF -> [B, 2] f32.

        Both bands are scored where they live: the low ring entry
        directly against the fresh spectral coefficients (the basis is
        orthonormal, so spectral L2 == spatial L2 — no synthesis), the
        high Hermite forecast against the fresh spatial high band.
        Each band is normalized by the *whole*-feature norm so the two
        rates add up to a bound on the full relative error.
        """
        low_spec, high = self._split(crf)
        low_pred = self._low_coeffs(state, ctx)
        high_pred = (base.ring_last(state.high) if self.high_order == 0
                     else base.ring_predict(state.high, ctx.t_now,
                                            self.high_order))

        def _sq(x):
            x = x.astype(jnp.float32)
            return jnp.sum(jnp.square(x), axis=tuple(range(1, x.ndim)))

        den = jnp.sqrt(jnp.maximum(_sq(low_spec) + _sq(high), 1e-12))
        e_low = jnp.sqrt(_sq(low_pred - low_spec)) / den
        e_high = jnp.sqrt(_sq(high_pred - high)) / den
        # warm lanes predict from underfilled rings — not a measurement
        valid = (state.n_valid >= self.needed_history).astype(jnp.float32)
        return jnp.stack([e_low * valid, e_high * valid], axis=-1)

    def observe(self, state, realized_error, ctx):
        return state._replace(rate_low=realized_error[:, 0],
                              rate_high=realized_error[:, 1])

    def error_feedback(self, state):
        return base.ErrorFeedback(realized=state.peak, events=state.events)


@registry.register("freqca_eb")
def _from_spec(spec) -> FreqCaErrorBudgetPolicy:
    # legacy specs carry no budget field; reuse the adaptive threshold
    return FreqCaErrorBudgetPolicy(
        interval=spec.interval, method=spec.method, rho=spec.rho,
        low_order=spec.low_order, high_order=spec.high_order,
        token_axis=spec.token_axis, budget=budget_tier(spec.tea_threshold))
