"""Cache-policy protocol: self-contained, jit-friendly policy objects.

A policy owns *all* of its state — including the adaptive carries that
used to live inside the sampler loop (TeaCache's accumulator, FreqCa-A's
skip counter and last-error scalar) — behind four methods:

* ``init(batch, feat_shape, ...)``  -> lane-major state pytree
* ``decide(state, ctx)``            -> ``(state, [B] bool mask)``
* ``update(state, crf, ctx)``       -> state with the fresh CRF pushed
* ``predict(state, ctx)``           -> ẑ_t reconstructed from the cache

``decide`` runs on *every* step and returns a **per-lane** activation
mask, so two requests sharing a serving batch can follow different
schedules (no more batch-global activation decisions).  Because the
sampler commits to executing exactly the returned mask, mask-dependent
bookkeeping (accumulator resets, skip counters) is applied inside
``decide``; ``update`` is merged back only into the activated lanes.

Every state leaf is **lane-major** (``[B, ...]``) so the sampler can
select per lane with a single broadcasted ``jnp.where`` (``lane_select``).

Policies are frozen dataclasses: hashable and compared by value, so a
policy instance (or a per-lane tuple of instances) can key a jit cache —
the serving engine compiles one executable per (bucket, lane-policy)
signature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    """Per-lane realized-error report extracted from a policy state.

    ``realized`` is the largest accumulated prediction error a lane
    committed between two consecutive full forwards (the quantity the
    per-request ``max_error`` SLO bounds); ``events`` counts the full
    forwards that were *triggered by the budget* (warm-up fills are
    excluded).  Policies without error feedback report none.
    """
    realized: jnp.ndarray          # [B] f32 — peak inter-full error
    events: jnp.ndarray            # [B] int32 — budget-triggered fulls


class StepContext(NamedTuple):
    """Per-step observation handed to the policy inside the sampler scan.

    Array fields are traced; ``batch`` / ``feat_shape`` / ``crf_dtype``
    are static python values (rebuilt each step, never part of the
    scan carry).
    """
    step_idx: jnp.ndarray          # [] int32 — index into the ts grid
    t_now: jnp.ndarray             # [] — current diffusion time
    x: jnp.ndarray                 # [B, *latent] — model input this step
    batch: int
    feat_shape: Tuple[int, ...]    # per-lane CRF feature shape
    crf_dtype: Any = jnp.float32

    def lane(self, j: int) -> "StepContext":
        """View of this context restricted to lane ``j``."""
        return self._replace(x=self.x[j:j + 1], batch=1)


class Ring(NamedTuple):
    """Lane-major ring of the K most recent activated features.

    Slots are **cyclic**: ``head[b]`` is the next slot lane ``b`` will
    overwrite, so a push touches one slot (``dynamic_update_slice``)
    instead of rewriting the whole ring the way the old ``jnp.roll``
    implementation did — O(S·D) per activated step, not O(K·S·D).
    Readers that need recency order (``ring_predict``) gather the slots
    through ``ring_ordered`` so the maths — and the bits — match the
    roll layout exactly.
    """
    vals: jnp.ndarray              # [B, K, *feat] cyclic slots
    ts: jnp.ndarray                # [B, K] activation timestamps
    head: jnp.ndarray              # [B] int32 — next slot to write


def ring_init(batch: int, k: int, feat_shape: Tuple[int, ...],
              dtype=jnp.float32) -> Ring:
    return Ring(vals=jnp.zeros((batch, k) + tuple(feat_shape), dtype),
                ts=jnp.full((batch, k), -1.0, jnp.float32),
                head=jnp.zeros((batch,), jnp.int32))


def ring_push(ring: Ring, value: jnp.ndarray, t) -> Ring:
    """Push a ``[B, *feat]`` value observed at scalar time ``t``.

    One slot written per lane (the per-lane ``dynamic_update_slice``
    lowers to a scatter under vmap); everything else aliases through.
    """
    k = ring.vals.shape[1]

    def write_one(vals, v, h):
        return jax.lax.dynamic_update_slice(
            vals, v[None].astype(vals.dtype),
            (h,) + (jnp.zeros((), jnp.int32),) * (vals.ndim - 1))

    vals = jax.vmap(write_one)(ring.vals, value, ring.head)
    slot = jnp.arange(k)[None, :] == ring.head[:, None]
    ts = jnp.where(slot, jnp.asarray(t, jnp.float32), ring.ts)
    return Ring(vals=vals, ts=ts, head=(ring.head + 1) % k)


def ring_order(ring: Ring) -> jnp.ndarray:
    """[B, K] slot permutation, oldest -> newest (head is the oldest)."""
    k = ring.ts.shape[1]
    return (ring.head[:, None] + jnp.arange(k)[None, :]) % k


def ring_ordered(ring: Ring) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(ts [B, K], vals [B, K, *feat]) gathered oldest -> newest —
    identical layout to the old roll-based ring."""
    idx = ring_order(ring)
    ts = jnp.take_along_axis(ring.ts, idx, axis=1)
    vidx = idx.reshape(idx.shape + (1,) * (ring.vals.ndim - 2))
    vals = jnp.take_along_axis(ring.vals, vidx, axis=1)
    return ts, vals


def ring_last(ring: Ring) -> jnp.ndarray:
    """Most recent cached value per lane -> [B, *feat] (order-0 reuse)."""
    k = ring.vals.shape[1]
    slot = (ring.head - 1) % k
    idx = slot.reshape((-1,) + (1,) * (ring.vals.ndim - 1))
    return jnp.take_along_axis(ring.vals, idx, axis=1)[:, 0]


def ring_weights(ring: Ring, t_query, order: int) -> jnp.ndarray:
    """Per-lane folded Hermite weights in recency order -> [B, K].

    Lanes activate at different times under per-lane schedules, so each
    carries its own timestamps; the per-lane normal-equation solve is
    folded host-side into K scalars (``ops.hermite_weights``), making
    prediction one contraction over the ring.
    """
    from repro.kernels import ops
    idx = ring_order(ring)
    ts = jnp.take_along_axis(ring.ts, idx, axis=1)
    return ops.hermite_weights(ts, t_query, order)


def ring_slot_weights(ring: Ring, t_query, order: int) -> jnp.ndarray:
    """Folded per-lane Hermite weights indexed by ring **slot** — lets a
    fused kernel consume ``ring.vals`` in memory order, permuting the K
    scalars instead of gathering the K feature tensors."""
    k = ring.ts.shape[1]
    w = ring_weights(ring, t_query, order)
    inv = (jnp.arange(k)[None, :] - ring.head[:, None]) % k
    return jnp.take_along_axis(w, inv, axis=1)


def ring_predict(ring: Ring, t_query, order: int) -> jnp.ndarray:
    """Per-lane Hermite forecast at ``t_query`` -> [B, *feat].

    ``hermite.predict`` is itself the folded-weights evaluation
    (w = B G⁻¹ b_q, then one FMA over the history), so this is the
    reference twin of the fused kernel path driven by
    ``ring_slot_weights`` — vmapped per lane, in recency order, to stay
    bit-identical with the pre-pointer ring."""
    from repro.core import hermite
    ts, vals = ring_ordered(ring)
    return jax.vmap(
        lambda t, v: hermite.predict(t, v, t_query, order))(ts, vals)


def lane_select(mask: jnp.ndarray, new, old):
    """Per-lane pytree merge: lane ``j`` takes ``new`` where ``mask[j]``."""
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def lane_mean_abs(x: jnp.ndarray) -> jnp.ndarray:
    """mean |x| per lane over all non-batch axes -> [B] float32."""
    return jnp.mean(jnp.abs(x.astype(jnp.float32)),
                    axis=tuple(range(1, x.ndim)))


def lane_rel_norm(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Per-lane relative L2 error ||pred − target|| / ||target|| -> [B]."""
    axes = tuple(range(1, target.ndim))
    p = pred.astype(jnp.float32)
    t = target.astype(jnp.float32)
    num = jnp.sqrt(jnp.sum(jnp.square(p - t), axis=axes))
    den = jnp.sqrt(jnp.sum(jnp.square(t), axis=axes))
    return num / jnp.maximum(den, 1e-6)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Base cache policy: scheduled activation every ``interval`` steps
    plus a warm-up of full steps until ``needed_history`` entries exist.

    Subclasses override ``init``/``update``/``predict`` (and ``decide``
    for adaptive policies).  The default ``decide`` assumes the state
    has an ``n_valid: [B] int32`` field — the per-lane count of
    activated steps — which every shipped policy state carries.
    """
    interval: int = 5

    name: ClassVar[str] = "abstract"
    # True when decide() can return lane-varying masks (adaptive
    # policies); False lets the sampler keep the scalar lax.cond path.
    per_lane: ClassVar[bool] = False
    # True when the policy consumes realized-error observations: the
    # sampler then measures the prediction error on every full step and
    # feeds it back through ``observe``.  Static, so policies that don't
    # opt in trace exactly as before (bit-identical programs).
    uses_error_feedback: ClassVar[bool] = False

    # --- protocol --------------------------------------------------------
    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, latent_shape: Tuple[int, ...] = (),
             latent_dtype=jnp.float32):
        """Build fresh per-batch cache state for one (batch, shape)
        signature.  ``feat_shape`` is the per-sample CRF shape
        ``(S, D)``: all derived quantities (spectral bands via
        ``kept_bins(S, rho)``, ring sizes, masks) must be functions of
        it, never of a config-global sequence length — a
        multi-resolution engine calls ``init`` once per rung of its
        shape ladder and each executable owns state sized for ITS
        ``S``.  Policy objects therefore stay shape-free (hashable,
        shared across every shape), and only the state is per-S."""
        raise NotImplementedError

    def decide(self, state, ctx: StepContext):
        """-> (state, [B] bool mask).  Runs every step."""
        scheduled = (ctx.step_idx % self.interval) == 0
        warm = state.n_valid < self.needed_history
        return state, jnp.broadcast_to(scheduled, warm.shape) | warm

    def update(self, state, crf: jnp.ndarray, ctx: StepContext):
        """Push the freshly computed CRF (activated lanes only — the
        sampler merges the result back under the decide mask)."""
        raise NotImplementedError

    def predict(self, state, ctx: StepContext) -> jnp.ndarray:
        """Reconstruct ẑ_t from the cache (cached lanes)."""
        raise NotImplementedError

    # --- error feedback (optional) ---------------------------------------
    def measure_error(self, state, crf: jnp.ndarray,
                      ctx: StepContext) -> jnp.ndarray:
        """Realized prediction error against the fresh CRF, per lane.

        Called by the sampler on full steps *before* ``update`` pushes
        the fresh feature (only when ``uses_error_feedback``), so the
        state still holds the cache the lane would have served.  The
        default scores the whole-feature relative L2 of ``predict``;
        policies may return any per-lane measurement their ``observe``
        understands (freqca_eb returns per-band errors).
        """
        return lane_rel_norm(self.predict(state, ctx), crf)

    def observe(self, state, realized_error: jnp.ndarray,
                ctx: StepContext):
        """Ingest a realized-error measurement (no-op by default).

        Runs on full steps, after ``update``; the sampler merges the
        result back only into the activated lanes.
        """
        return state

    def error_feedback(self, state) -> Optional[ErrorFeedback]:
        """Extract the realized-error report from a final state, or
        ``None`` for policies that track no feedback."""
        return None

    def with_budget(self, max_error: Optional[float]) -> "Policy":
        """Specialize this policy to a per-request error budget.

        ``None`` (no SLO) and policies without error feedback return
        ``self`` unchanged, keeping request grouping and compiled
        signatures exactly as before.
        """
        return self

    # --- metadata --------------------------------------------------------
    def compatibility_key(self) -> Tuple:
        """Hashable batch-compatibility signature for the scheduler.

        Two requests may share a policy-homogeneous batch iff their
        policies' keys are equal.  Static-schedule policies
        (``per_lane=False``) are keyed by the activation schedule they
        produce — ``(interval, needed_history)`` — because their
        ``decide`` masks depend only on ``step_idx`` and the
        deterministically advancing ``n_valid``, so same-key lanes
        activate on exactly the same steps and never force a forward the
        others didn't already schedule (e.g. ``fora(interval=1)`` and
        ``none`` are one family).  Adaptive policies key on their full
        value: a data-dependent mask can only share a batch with lanes
        budgeting errors the identical way — anything looser reintroduces
        the every-lane-pays-for-one-activation coupling grouping exists
        to remove.
        """
        if self.per_lane:
            return ("adaptive", self)
        return ("sched", self.interval, self.needed_history)

    @property
    def needed_history(self) -> int:
        """Activated steps required before prediction is well-posed —
        drives the warm-up length (no hard-coded constants)."""
        return 1

    @property
    def cache_units(self) -> int:
        """Feature-sized tensors held per lane (paper §4.4.1)."""
        return 1

    def state_bytes(self, state) -> int:
        """Actual cache footprint — policy states hold no dummy slots,
        so this is exact by construction."""
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state))
