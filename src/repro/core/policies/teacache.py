"""TeaCache-style adaptive reuse, now with per-lane activation.

Each lane accumulates the relative change of *its own* model input
``x_t`` between steps and triggers a full forward when the accumulator
crosses ``tea_threshold`` (the interval schedule is ignored);
prediction = reuse, like FORA.  The accumulator and previous-input
carries — sampler-resident state before the policy-object redesign —
live in the policy state, and every lane resets independently, so mixed
workloads sharing a batch no longer couple their activation decisions.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry


class TeaCacheState(NamedTuple):
    hist: base.Ring                # [B, 1, *feat] last full CRF
    n_valid: jnp.ndarray           # [B] int32
    acc: jnp.ndarray               # [B] f32 accumulated relative change
    prev_x: jnp.ndarray            # [B, *latent] previous model input


@dataclasses.dataclass(frozen=True)
class TeaCachePolicy(base.Policy):
    name = "teacache"
    per_lane = True

    tea_threshold: float = 0.15

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, latent_shape: Tuple[int, ...] = (),
             latent_dtype=jnp.float32):
        return TeaCacheState(
            hist=base.ring_init(batch, 1, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32),
            acc=jnp.zeros((batch,), jnp.float32),
            prev_x=jnp.zeros((batch,) + tuple(latent_shape), latent_dtype))

    def decide(self, state, ctx):
        rel = base.lane_mean_abs(ctx.x - state.prev_x) / jnp.maximum(
            base.lane_mean_abs(state.prev_x), 1e-6)
        acc = state.acc + rel
        act = ((state.n_valid < 1) | (acc > self.tea_threshold)
               | (ctx.step_idx == 0))
        return state._replace(
            acc=jnp.where(act, 0.0, acc),
            prev_x=ctx.x.astype(state.prev_x.dtype)), act

    def update(self, state, crf, ctx):
        return state._replace(
            hist=base.ring_push(state.hist, crf, ctx.t_now),
            n_valid=state.n_valid + 1)

    def predict(self, state, ctx):
        return base.ring_last(state.hist)


@registry.register("teacache")
def _from_spec(spec) -> TeaCachePolicy:
    return TeaCachePolicy(interval=spec.interval,
                          tea_threshold=spec.tea_threshold)
