"""Registry of self-contained cache-policy objects (FreqCa + family).

Policies implement the four-method protocol in :mod:`.base` and register
a ``spec -> Policy`` factory in :mod:`.registry`; the diffusion sampler
drives them through a per-lane :class:`~.registry.PolicyBank` and never
dispatches on policy names.  Policy objects are the public construction
route — build them directly (``FreqCaPolicy(interval=5)``).  The legacy
``repro.core.cache.CachePolicy`` string-kind spec is deprecated:
``.resolve()`` still works (one DeprecationWarning) and ``resolve``
here still accepts specs for the shim's sake.
"""
from repro.core.policies.base import (ErrorFeedback, Policy,  # noqa: F401
                                      Ring, StepContext, lane_select)
from repro.core.policies.foca import FoCaPolicy  # noqa: F401
from repro.core.policies.fora import ForaPolicy  # noqa: F401
from repro.core.policies.freqca import FreqCaPolicy  # noqa: F401
from repro.core.policies.freqca_a import FreqCaAdaptivePolicy  # noqa: F401
from repro.core.policies.freqca_eb import (ERROR_TIERS,  # noqa: F401
                                           FreqCaErrorBudgetPolicy,
                                           budget_tier)
from repro.core.policies.none import NoCachePolicy  # noqa: F401
from repro.core.policies.registry import (PolicyBank, available,  # noqa: F401
                                          bank, compatibility_key, register,
                                          resolve)
from repro.core.policies.taylorseer import TaylorSeerPolicy  # noqa: F401
from repro.core.policies.teacache import TeaCachePolicy  # noqa: F401
