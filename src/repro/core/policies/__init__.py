"""Registry of self-contained cache-policy objects (FreqCa + family).

Policies implement the four-method protocol in :mod:`.base` and register
a ``spec -> Policy`` factory in :mod:`.registry`; the diffusion sampler
drives them through a per-lane :class:`~.registry.PolicyBank` and never
dispatches on policy names.  ``repro.core.cache.CachePolicy`` remains
the user-facing spec; ``.resolve()`` turns it into the registered
object.
"""
from repro.core.policies.base import (Policy, Ring, StepContext,  # noqa: F401
                                      lane_select)
from repro.core.policies.foca import FoCaPolicy  # noqa: F401
from repro.core.policies.fora import ForaPolicy  # noqa: F401
from repro.core.policies.freqca import FreqCaPolicy  # noqa: F401
from repro.core.policies.freqca_a import FreqCaAdaptivePolicy  # noqa: F401
from repro.core.policies.none import NoCachePolicy  # noqa: F401
from repro.core.policies.registry import (PolicyBank, available,  # noqa: F401
                                          bank, compatibility_key, register,
                                          resolve)
from repro.core.policies.taylorseer import TaylorSeerPolicy  # noqa: F401
from repro.core.policies.teacache import TeaCachePolicy  # noqa: F401
