"""FreqCa (the paper's policy): frequency-split CRF caching.

The cached Cumulative Residual Feature is decomposed into a low band —
reused directly (order ``low_order``, default 0) or Hermite-predicted —
and a high band forecast with an order-``high_order`` Hermite fit over
the ``k_high`` most recent activated steps (paper §3.2, eq. 1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core import frequency
from repro.core.policies import base, registry


class FreqCaState(NamedTuple):
    low: base.Ring                 # [B, K_low,  *feat] spatial low band
    high: base.Ring                # [B, K_high, *feat] spatial high band
    n_valid: jnp.ndarray           # [B] int32 — activated steps per lane


@dataclasses.dataclass(frozen=True)
class FreqCaPolicy(base.Policy):
    name = "freqca"

    method: str = "dct"            # fft | dct | none
    rho: float = 0.0625            # low-frequency fraction of the spectrum
    low_order: int = 0             # 0 = direct reuse (paper default)
    high_order: int = 2            # Hermite order for the high band
    token_axis: int = 1            # token axis of the per-lane [B, S, D] CRF

    @property
    def k_low(self) -> int:
        return self.low_order + 1

    @property
    def k_high(self) -> int:
        return self.high_order + 1

    @property
    def needed_history(self) -> int:
        return max(self.k_low, self.k_high)

    @property
    def cache_units(self) -> int:
        return self.k_low + self.k_high

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return FreqCaState(
            low=base.ring_init(batch, self.k_low, feat_shape, crf_dtype),
            high=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32))

    def update(self, state, crf, ctx):
        bands = frequency.decompose(crf, self.rho, self.method,
                                    axis=self.token_axis)
        return state._replace(
            low=base.ring_push(state.low, bands.low, ctx.t_now),
            high=base.ring_push(state.high, bands.high, ctx.t_now),
            n_valid=state.n_valid + 1)

    def predict(self, state, ctx):
        low = (base.ring_last(state.low) if self.low_order == 0 else
               base.ring_predict(state.low, ctx.t_now, self.low_order))
        high = (base.ring_last(state.high) if self.high_order == 0 else
                base.ring_predict(state.high, ctx.t_now, self.high_order))
        return low + high


@registry.register("freqca")
def _from_spec(spec) -> FreqCaPolicy:
    return FreqCaPolicy(interval=spec.interval, method=spec.method,
                        rho=spec.rho, low_order=spec.low_order,
                        high_order=spec.high_order,
                        token_axis=spec.token_axis)
