"""FreqCa (the paper's policy): frequency-split CRF caching with a
**spectral** low-band ring.

The cached Cumulative Residual Feature is decomposed into a low band —
held as ``m = spectral_kept_bins(S, rho, method)`` frequency-domain
coefficient rows, ~``rho`` of the spatial footprint (SpectralCache,
arXiv 2603.05315) — and a spatial high band forecast with an
order-``high_order`` Hermite fit over the ``k_high`` most recent
activated steps (paper §3.2, eq. 1).

Both halves of the cache datapath go through the kernel dispatch layer
(``repro.kernels.ops``): ``update`` is one fused analysis pass emitting
``(low_spec, high)`` without ever materialising the spatial low band,
and ``predict`` fuses the ``[S, m]`` synthesis matmul with the K-entry
Hermite FMA (folded per-lane weights) — on the Pallas backend the
cached step is a single pass over HBM.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core import frequency
from repro.core.policies import base, registry
from repro.kernels import ops


class FreqCaState(NamedTuple):
    low: base.Ring                 # [B, K_low,  *feat|m] SPECTRAL low band
    high: base.Ring                # [B, K_high, *feat] spatial high band
    n_valid: jnp.ndarray           # [B] int32 — activated steps per lane


@dataclasses.dataclass(frozen=True)
class FreqCaPolicy(base.Policy):
    name = "freqca"

    method: str = "dct"            # fft | dct | none
    rho: float = 0.0625            # low-frequency fraction of the spectrum
    low_order: int = 0             # 0 = direct reuse (paper default)
    high_order: int = 2            # Hermite order for the high band
    token_axis: int = 1            # token axis of the per-lane [B, S, D] CRF

    @property
    def k_low(self) -> int:
        return self.low_order + 1

    @property
    def k_high(self) -> int:
        return self.high_order + 1

    @property
    def needed_history(self) -> int:
        return max(self.k_low, self.k_high)

    @property
    def cache_units(self) -> int:
        """Paper §4.4.1 feature-tensor accounting (the spectral low ring
        actually occupies ~``rho`` of its unit — see ``state_bytes``)."""
        return self.k_low + self.k_high

    # --- spectral layout --------------------------------------------------
    def spectral_bins(self, s: int) -> int:
        return frequency.spectral_kept_bins(s, self.rho, self.method)

    def low_feat_shape(self, feat_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        """Per-lane low-ring shape: the token axis shrinks S -> m."""
        ax = self.token_axis - 1
        s = feat_shape[ax]
        return feat_shape[:ax] + (self.spectral_bins(s),) + feat_shape[
            ax + 1:]

    def _fusable(self, feat_shape: Tuple[int, ...]) -> bool:
        # the fused kernels take the [B, S, D] token-major layout
        return len(feat_shape) == 2 and self.token_axis == 1

    def _split(self, crf: jnp.ndarray):
        """CRF -> (low_spec, high) through the dispatch layer."""
        if self._fusable(crf.shape[1:]):
            return ops.band_split_spectral(crf, self.rho, self.method)
        x = jnp.moveaxis(crf, self.token_axis, -2).astype(jnp.float32)
        basis = frequency.low_band_basis(x.shape[-2], self.rho, self.method)
        low_spec = jnp.einsum("ms,...sd->...md", basis, x)
        high = x - jnp.einsum("ms,...md->...sd", basis, low_spec)
        return (jnp.moveaxis(low_spec, -2, self.token_axis).astype(crf.dtype),
                jnp.moveaxis(high, -2, self.token_axis).astype(crf.dtype))

    def _synthesize(self, low_spec: jnp.ndarray, s: int) -> jnp.ndarray:
        """Spectral low ring entry -> spatial low band (Bᵀ·coeffs)."""
        basis = frequency.low_band_basis(s, self.rho, self.method)
        x = jnp.moveaxis(low_spec, self.token_axis, -2).astype(jnp.float32)
        low = jnp.einsum("ms,...md->...sd", basis, x)
        return jnp.moveaxis(low, -2, self.token_axis).astype(low_spec.dtype)

    # --- protocol ---------------------------------------------------------
    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return FreqCaState(
            low=base.ring_init(batch, self.k_low,
                               self.low_feat_shape(feat_shape), crf_dtype),
            high=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32))

    def update(self, state, crf, ctx):
        low_spec, high = self._split(crf)
        return state._replace(
            low=base.ring_push(state.low, low_spec, ctx.t_now),
            high=base.ring_push(state.high, high, ctx.t_now),
            n_valid=state.n_valid + 1)

    def _low_coeffs(self, state, ctx):
        return (base.ring_last(state.low) if self.low_order == 0 else
                base.ring_predict(state.low, ctx.t_now, self.low_order))

    def predict(self, state, ctx):
        s = ctx.feat_shape[self.token_axis - 1]
        low_spec = self._low_coeffs(state, ctx)
        if (ops.use_pallas() and self.high_order > 0
                and self._fusable(ctx.feat_shape)):
            # one fused pass: synthesis matmul + K-entry Hermite FMA,
            # consuming the high ring in slot order (the K folded
            # weights are permuted instead of the K feature tensors)
            synth = frequency.low_band_basis(s, self.rho, self.method).T
            w = base.ring_slot_weights(state.high, ctx.t_now,
                                       self.high_order)
            return ops.freqca_predict_spectral(low_spec, synth,
                                               state.high.vals, w)
        low = self._synthesize(low_spec, s)
        high = (base.ring_last(state.high) if self.high_order == 0 else
                base.ring_predict(state.high, ctx.t_now, self.high_order))
        return low + high


@registry.register("freqca")
def _from_spec(spec) -> FreqCaPolicy:
    return FreqCaPolicy(interval=spec.interval, method=spec.method,
                        rho=spec.rho, low_order=spec.low_order,
                        high_order=spec.high_order,
                        token_axis=spec.token_axis)
