"""Policy registry + per-lane policy banks.

``register(name)`` decorates a factory ``spec -> Policy`` so new
policies (FoCa, SpectralCache, ...) plug in without touching the
sampler.  ``resolve`` accepts a registered name's spec (the legacy
``repro.core.cache.CachePolicy`` dataclass, dispatched on ``.kind``) or
an already-built :class:`~repro.core.policies.base.Policy` instance.

``bank(policy, batch)`` turns a policy — or a per-lane sequence of
policies — into a :class:`PolicyBank`, the object the sampler actually
drives.  A bank exposes the same four-method protocol batched over
lanes plus two static flags:

* ``scalar_decision`` — the mask is batch-uniform by construction
  (single non-adaptive policy), so the sampler may branch with a scalar
  ``lax.cond`` and skip the per-lane select entirely (the seed fast
  path, preserved bit-for-bit).
* ``always_full`` — every lane is the ``none`` policy; no branch at all.

Mixed banks hold one state pytree per lane (static tuple — fine at
serving batch sizes) so lanes with different policies, and therefore
different state *structures*, share one compiled executable.
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.core.policies import base

_FACTORIES: Dict[str, Callable] = {}


def register(name: str):
    """Decorator: register a ``spec -> Policy`` factory under ``name``."""
    def deco(factory: Callable) -> Callable:
        _FACTORIES[name] = factory
        return factory
    return deco


def _ensure_builtin() -> None:
    # import for registration side effects; lazy to avoid import cycles
    from repro.core.policies import (foca, fora, freqca,  # noqa: F401
                                     freqca_a, freqca_eb, none, taylorseer,
                                     teacache)


def available() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_FACTORIES))


def resolve(policy) -> base.Policy:
    """Spec (``.kind``-dispatched) or Policy instance -> Policy instance."""
    if isinstance(policy, base.Policy):
        return policy
    kind = getattr(policy, "kind", None)
    if kind is None:
        raise TypeError(
            f"expected a Policy or a spec with a .kind, got {policy!r}")
    _ensure_builtin()
    if kind not in _FACTORIES:
        raise KeyError(f"unknown cache policy {kind!r}; "
                       f"registered: {available()}")
    return _FACTORIES[kind](policy)


def compatibility_key(policy) -> Tuple:
    """Batch-compatibility key of a policy or spec (see
    :meth:`~repro.core.policies.base.Policy.compatibility_key`).  The
    scheduler groups requests by this key so every cut batch is
    policy-homogeneous."""
    return resolve(policy).compatibility_key()


# ---------------------------------------------------------------------------
# per-lane banks
# ---------------------------------------------------------------------------

class PolicyBank:
    """Per-lane policy assignment for one sampler batch (abstract)."""
    scalar_decision: bool
    always_full: bool
    # any lane consumes realized-error observations (static: the
    # sampler only adds the measure/observe ops when True, so banks
    # without feedback trace bit-identically to before)
    uses_error_feedback: bool = False
    batch: int

    def compatibility_key(self):
        """Single key when every lane is batch-compatible, else the
        per-lane key tuple (only ungrouped schedulers cut such banks)."""
        raise NotImplementedError

    def init(self, feat_shape, crf_dtype, latent_shape, latent_dtype):
        raise NotImplementedError

    def decide(self, state, ctx: base.StepContext):
        raise NotImplementedError

    def apply_update(self, state, crf, ctx: base.StepContext, mask):
        """Push ``crf`` and merge the result into the masked lanes."""
        raise NotImplementedError

    def predict(self, state, ctx: base.StepContext):
        raise NotImplementedError

    # --- error feedback ---------------------------------------------------
    def measure_error(self, state, crf, ctx: base.StepContext):
        """Per-lane realized-error measurement (pre-update state)."""
        raise NotImplementedError

    def observe(self, state, err, ctx: base.StepContext, mask):
        """Feed measurements back, merged into the masked lanes only
        (a lane alone would not have measured on a step it skipped)."""
        raise NotImplementedError

    def error_feedback(self, state):
        """[B]-shaped :class:`~repro.core.policies.base.ErrorFeedback`
        extracted from the final state, or ``None``."""
        return None


class UniformBank(PolicyBank):
    """Every lane runs the same policy; state is batched in one pytree."""

    def __init__(self, policy: base.Policy, batch: int):
        self.policy = policy
        self.batch = batch
        self.scalar_decision = not policy.per_lane
        self.always_full = policy.name == "none"
        self.uses_error_feedback = policy.uses_error_feedback

    def compatibility_key(self):
        return self.policy.compatibility_key()

    def init(self, feat_shape, crf_dtype, latent_shape, latent_dtype):
        return self.policy.init(self.batch, feat_shape, crf_dtype,
                                latent_shape=latent_shape,
                                latent_dtype=latent_dtype)

    def decide(self, state, ctx):
        return self.policy.decide(state, ctx)

    def apply_update(self, state, crf, ctx, mask):
        new = self.policy.update(state, crf, ctx)
        if self.scalar_decision:
            # the sampler only enters the full branch when the (uniform)
            # mask is True, so every lane activated — no select needed
            return new
        return base.lane_select(mask, new, state)

    def predict(self, state, ctx):
        return self.policy.predict(state, ctx)

    def measure_error(self, state, crf, ctx):
        return self.policy.measure_error(state, crf, ctx)

    def observe(self, state, err, ctx, mask):
        new = self.policy.observe(state, err, ctx)
        return base.lane_select(mask, new, state)

    def error_feedback(self, state):
        return self.policy.error_feedback(state)


class MixedBank(PolicyBank):
    """One policy per lane; state is a static tuple of lane-1 pytrees."""

    def __init__(self, policies: Sequence[base.Policy]):
        self.policies = tuple(policies)
        self.batch = len(self.policies)
        self.scalar_decision = False
        self.always_full = all(p.name == "none" for p in self.policies)
        self.uses_error_feedback = any(p.uses_error_feedback
                                       for p in self.policies)

    def compatibility_key(self):
        keys = tuple(p.compatibility_key() for p in self.policies)
        return keys[0] if all(k == keys[0] for k in keys) else keys

    def init(self, feat_shape, crf_dtype, latent_shape, latent_dtype):
        return tuple(p.init(1, feat_shape, crf_dtype,
                            latent_shape=latent_shape,
                            latent_dtype=latent_dtype)
                     for p in self.policies)

    def decide(self, state, ctx):
        states, masks = [], []
        for j, pol in enumerate(self.policies):
            st, m = pol.decide(state[j], ctx.lane(j))
            states.append(st)
            masks.append(m)
        return tuple(states), jnp.concatenate(masks)

    def apply_update(self, state, crf, ctx, mask):
        out = []
        for j, pol in enumerate(self.policies):
            new = pol.update(state[j], crf[j:j + 1], ctx.lane(j))
            out.append(base.lane_select(mask[j:j + 1], new, state[j]))
        return tuple(out)

    def predict(self, state, ctx):
        return jnp.concatenate([
            pol.predict(state[j], ctx.lane(j))
            for j, pol in enumerate(self.policies)])

    def measure_error(self, state, crf, ctx):
        # per-lane tuple: error shapes may differ across policies
        # (freqca_eb reports per-band pairs); None for lanes that
        # consume no feedback
        return tuple(
            pol.measure_error(state[j], crf[j:j + 1], ctx.lane(j))
            if pol.uses_error_feedback else None
            for j, pol in enumerate(self.policies))

    def observe(self, state, err, ctx, mask):
        out = []
        for j, pol in enumerate(self.policies):
            if pol.uses_error_feedback:
                new = pol.observe(state[j], err[j], ctx.lane(j))
                out.append(base.lane_select(mask[j:j + 1], new, state[j]))
            else:
                out.append(state[j])
        return tuple(out)

    def error_feedback(self, state):
        if not self.uses_error_feedback:
            return None
        parts = []
        for j, pol in enumerate(self.policies):
            fb = pol.error_feedback(state[j])
            if fb is None:
                fb = base.ErrorFeedback(
                    realized=jnp.zeros((1,), jnp.float32),
                    events=jnp.zeros((1,), jnp.int32))
            parts.append(fb)
        return base.ErrorFeedback(
            realized=jnp.concatenate([p.realized for p in parts]),
            events=jnp.concatenate([p.events for p in parts]))


PolicyLike = Union[base.Policy, object]


def bank(policy: Union[PolicyLike, Sequence[PolicyLike]],
         batch: int) -> PolicyBank:
    """Policy / spec / per-lane sequence thereof -> PolicyBank."""
    if isinstance(policy, (list, tuple)):
        lanes = tuple(resolve(p) for p in policy)
        if len(lanes) != batch:
            raise ValueError(f"got {len(lanes)} lane policies for "
                             f"batch {batch}")
        if all(p == lanes[0] for p in lanes):
            return UniformBank(lanes[0], batch)
        return MixedBank(lanes)
    return UniformBank(resolve(policy), batch)
