"""TaylorSeer baseline: whole-feature polynomial forecast (no bands).

The paper's main forecast baseline — an order-``high_order`` Hermite
extrapolation of the full CRF from the ``high_order + 1`` most recent
activated steps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry


class ForecastState(NamedTuple):
    hist: base.Ring                # [B, K, *feat] whole-feature history
    n_valid: jnp.ndarray           # [B] int32


@dataclasses.dataclass(frozen=True)
class TaylorSeerPolicy(base.Policy):
    name = "taylorseer"

    high_order: int = 2

    @property
    def k_high(self) -> int:
        return self.high_order + 1

    @property
    def needed_history(self) -> int:
        return self.k_high

    @property
    def cache_units(self) -> int:
        return self.k_high

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return ForecastState(
            hist=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32))

    def update(self, state, crf, ctx):
        return ForecastState(
            hist=base.ring_push(state.hist, crf, ctx.t_now),
            n_valid=state.n_valid + 1)

    def predict(self, state, ctx):
        return base.ring_predict(state.hist, ctx.t_now, self.high_order)


@registry.register("taylorseer")
def _from_spec(spec) -> TaylorSeerPolicy:
    return TaylorSeerPolicy(interval=spec.interval,
                            high_order=spec.high_order)
