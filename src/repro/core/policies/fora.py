"""FORA baseline: whole-feature reuse (order-0 cache).

The paper's main reuse baseline — cached steps replay the CRF of the
most recent activated step unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry
from repro.core.policies.taylorseer import ForecastState


@dataclasses.dataclass(frozen=True)
class ForaPolicy(base.Policy):
    name = "fora"

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return ForecastState(
            hist=base.ring_init(batch, 1, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32))

    def update(self, state, crf, ctx):
        return ForecastState(
            hist=base.ring_push(state.hist, crf, ctx.t_now),
            n_valid=state.n_valid + 1)

    def predict(self, state, ctx):
        return base.ring_last(state.hist)


@registry.register("fora")
def _from_spec(spec) -> ForaPolicy:
    return ForaPolicy(interval=spec.interval)
