"""No caching: every lane activates every step (ground truth / baseline
latency).  The prediction path is never *used*, but it is still traced
inside mixed batches, so ``predict`` returns well-formed zeros.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry


class NoCacheState(NamedTuple):
    n_valid: jnp.ndarray           # [B] int32


@dataclasses.dataclass(frozen=True)
class NoCachePolicy(base.Policy):
    name = "none"

    @property
    def cache_units(self) -> int:
        return 0

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return NoCacheState(n_valid=jnp.zeros((batch,), jnp.int32))

    def decide(self, state, ctx):
        return state, jnp.ones((ctx.batch,), bool)

    def update(self, state, crf, ctx):
        return NoCacheState(n_valid=state.n_valid + 1)

    def predict(self, state, ctx):
        return jnp.zeros((ctx.batch,) + tuple(ctx.feat_shape),
                         ctx.crf_dtype)


@registry.register("none")
def _from_spec(spec) -> NoCachePolicy:
    return NoCachePolicy(interval=1)
