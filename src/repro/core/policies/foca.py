"""FoCa-style forecast-then-calibrate policy (cf. arXiv 2508.16211).

Registered to prove the registry absorbs new members of the policy
family without touching the sampler.  Forecast = TaylorSeer's Hermite
extrapolation of the whole CRF; calibrate = at every activated step the
stale forecast for that step is scored against the fresh CRF and a
per-lane scalar gain ``γ = ⟨forecast, crf⟩ / ||forecast||²`` (clipped to
``[1/calib_clip, calib_clip]``) is refit, then applied to subsequent
cached-step forecasts.  A drifting forecast is pulled back toward the
observed trajectory instead of being replayed verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry


class FoCaState(NamedTuple):
    hist: base.Ring                # [B, K, *feat]
    n_valid: jnp.ndarray           # [B] int32
    gain: jnp.ndarray              # [B] f32 calibration gain


@dataclasses.dataclass(frozen=True)
class FoCaPolicy(base.Policy):
    name = "foca"

    high_order: int = 2
    calib_clip: float = 2.0        # gain clipped to [1/clip, clip]

    @property
    def k_high(self) -> int:
        return self.high_order + 1

    @property
    def needed_history(self) -> int:
        return self.k_high

    @property
    def cache_units(self) -> int:
        return self.k_high

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return FoCaState(
            hist=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32),
            gain=jnp.ones((batch,), jnp.float32))

    def update(self, state, crf, ctx):
        pred = base.ring_predict(state.hist, ctx.t_now, self.high_order)
        axes = tuple(range(1, crf.ndim))
        p = pred.astype(jnp.float32)
        c = crf.astype(jnp.float32)
        g = (jnp.sum(p * c, axis=axes)
             / (jnp.sum(p * p, axis=axes) + 1e-6))
        g = jnp.clip(g, 1.0 / self.calib_clip, self.calib_clip)
        # only calibrate once the ring is full — earlier forecasts are fit
        # on zero-padded history and would poison the gain
        gain = jnp.where(state.n_valid >= self.needed_history, g, 1.0)
        return FoCaState(
            hist=base.ring_push(state.hist, crf, ctx.t_now),
            n_valid=state.n_valid + 1,
            gain=gain)

    def predict(self, state, ctx):
        pred = base.ring_predict(state.hist, ctx.t_now, self.high_order)
        g = state.gain.reshape(state.gain.shape + (1,) * (pred.ndim - 1))
        return (g * pred.astype(jnp.float32)).astype(pred.dtype)


@registry.register("foca")
def _from_spec(spec) -> FoCaPolicy:
    return FoCaPolicy(interval=spec.interval, high_order=spec.high_order)
