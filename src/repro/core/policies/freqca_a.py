"""FreqCa-A (beyond paper): FreqCa predictor + self-calibrated adaptive
schedule, per lane.

At every activated step the cache already contains what FreqCa *would
have predicted* for that step, so its relative error against the fresh
CRF is free to measure.  A lane then skips while the projected error of
the next cached step — ``(steps_since_full + 1) · err_last`` — stays
under ``tea_threshold``.  The skip counter and last-error scalar are
policy state (per lane), and the warm-up length is derived from the
predictor's ``needed_history`` instead of a hard-coded constant, so
non-default ``high_order`` never samples from an underfilled ring.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.policies import base, registry
from repro.core.policies.freqca import FreqCaPolicy


class FreqCaAState(NamedTuple):
    low: base.Ring                 # [B, K_low,  *feat|m] SPECTRAL low band
    high: base.Ring                # [B, K_high, *feat]
    n_valid: jnp.ndarray           # [B] int32
    since: jnp.ndarray             # [B] int32 — steps since last full
    err_last: jnp.ndarray          # [B] f32 — last measured pred error


@dataclasses.dataclass(frozen=True)
class FreqCaAdaptivePolicy(FreqCaPolicy):
    name = "freqca_a"
    per_lane = True

    tea_threshold: float = 0.15

    def init(self, batch: int, feat_shape: Tuple[int, ...],
             crf_dtype=jnp.float32, **_):
        return FreqCaAState(
            low=base.ring_init(batch, self.k_low,
                               self.low_feat_shape(feat_shape), crf_dtype),
            high=base.ring_init(batch, self.k_high, feat_shape, crf_dtype),
            n_valid=jnp.zeros((batch,), jnp.int32),
            since=jnp.zeros((batch,), jnp.int32),
            err_last=jnp.zeros((batch,), jnp.float32))

    def decide(self, state, ctx):
        warm = state.n_valid < self.needed_history
        projected = (state.since.astype(jnp.float32) + 1.0) * state.err_last
        act = warm | (projected > self.tea_threshold)
        # the sampler commits to this mask, so the skip counter resets
        # here; update() below only runs on the activated lanes
        return state._replace(
            since=jnp.where(act, 0, state.since + 1)), act

    def update(self, state, crf, ctx):
        # score the prediction FreqCa would have made for THIS step
        # against the fresh CRF (self-calibration, free at full steps)
        err = base.lane_rel_norm(self.predict(state, ctx), crf)
        low_spec, high = self._split(crf)
        return state._replace(
            low=base.ring_push(state.low, low_spec, ctx.t_now),
            high=base.ring_push(state.high, high, ctx.t_now),
            n_valid=state.n_valid + 1,
            err_last=err)


@registry.register("freqca_a")
def _from_spec(spec) -> FreqCaAdaptivePolicy:
    return FreqCaAdaptivePolicy(interval=spec.interval, method=spec.method,
                                rho=spec.rho, low_order=spec.low_order,
                                high_order=spec.high_order,
                                token_axis=spec.token_axis,
                                tea_threshold=spec.tea_threshold)
