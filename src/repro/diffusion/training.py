"""Rectified-flow training loss for denoisers."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.diffusion import schedule


def rf_loss(apply_fn: Callable, params, batch: Dict[str, jnp.ndarray],
            rng: jax.Array):
    """apply_fn(params, x_t, t) -> velocity. batch['latents']: [B,H,W,C]."""
    x = batch["latents"]
    b = x.shape[0]
    k_t, k_n = jax.random.split(rng)
    # logit-normal time sampling (SD3/FLUX recipe)
    t = jax.nn.sigmoid(jax.random.normal(k_t, (b,)))
    noise = jax.random.normal(k_n, x.shape, x.dtype)
    x_t = schedule.add_noise(x, noise, t)
    target = schedule.velocity_target(x, noise)
    v = apply_fn(params, x_t, t)
    loss = jnp.mean(jnp.square(v.astype(jnp.float32)
                               - target.astype(jnp.float32)))
    return loss, {"loss": loss}
