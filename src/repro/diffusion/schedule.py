"""Rectified-flow schedule (FLUX/Qwen-Image family).

Forward process: x_t = (1 - t)·x_data + t·noise, t ∈ [0, 1].
The model predicts velocity v = noise − x_data; sampling integrates
dx/dt = v from t=1 (noise) to t=0 (data) with Euler steps.
"""
from __future__ import annotations

import jax.numpy as jnp


def timesteps(n_steps: int, shift: float = 1.0) -> jnp.ndarray:
    """Decreasing times t_0=1 … t_N=0 (N+1 knots for N Euler steps).

    ``shift`` > 1 spends more steps near t=1 (the resolution-dependent
    schedule shift used by FLUX).
    """
    u = jnp.linspace(1.0, 0.0, n_steps + 1)
    return (shift * u) / (1.0 + (shift - 1.0) * u)


def add_noise(x_data: jnp.ndarray, noise: jnp.ndarray, t) -> jnp.ndarray:
    t = jnp.asarray(t, x_data.dtype)
    while t.ndim < x_data.ndim:
        t = t[..., None]
    return (1.0 - t) * x_data + t * noise


def velocity_target(x_data: jnp.ndarray, noise: jnp.ndarray) -> jnp.ndarray:
    return noise - x_data
