"""Diffusion sampling loop with pluggable feature-cache policy.

The whole sampler is one ``lax.scan`` over timesteps; each step is a
``lax.cond`` between the *activated* branch (full denoiser forward +
cache update) and the *cached* branch (FreqCa/baseline prediction of the
CRF + the final layer only).  One compiled program regardless of policy.

The denoiser is abstract: ``full_fn(x, t) -> (velocity, crf)`` and
``from_crf_fn(crf, t) -> velocity``; both DiT and backbone-wrapped
assigned architectures plug in (repro.models.dit).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core.cache import CachePolicy


class SampleResult(NamedTuple):
    x: jnp.ndarray                  # final latents
    n_full: jnp.ndarray             # number of activated (full) steps
    trajectory: Optional[jnp.ndarray] = None


def sample(full_fn: Callable, from_crf_fn: Callable, x_init: jnp.ndarray,
           ts: jnp.ndarray, policy: CachePolicy,
           crf_shape: Tuple[int, ...], crf_dtype=jnp.float32,
           return_trajectory: bool = False) -> SampleResult:
    """Euler rectified-flow sampling from t=1 to t=0 under a cache policy.

    ts: [n_steps+1] decreasing times.  crf_shape: shape of the CRF
    feature (needed to build the static cache state).
    """
    n_steps = ts.shape[0] - 1
    state0 = cache_lib.init_state(policy, crf_shape, crf_dtype)
    # adaptive carries: (accumulator, previous input, steps-since-full,
    # last measured prediction error)
    tea0 = (jnp.zeros((), jnp.float32), jnp.zeros_like(x_init),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))

    def step(carry, inp):
        x, state, tea = carry
        i, t_now, t_next = inp
        acc, prev_x, since, err_last = tea

        def full_branch(op):
            x_, state_ = op
            v, crf = full_fn(x_, t_now)
            if policy.kind == "freqca_a":
                # the prediction FreqCa would have made for THIS step is
                # free to score against the fresh CRF (self-calibration)
                pred = cache_lib.predict(policy, state_, t_now)
                err = jnp.linalg.norm((pred - crf).astype(jnp.float32)) /                     jnp.maximum(jnp.linalg.norm(crf.astype(jnp.float32)),
                                1e-6)
            else:
                err = jnp.zeros((), jnp.float32)
            return v, cache_lib.update(policy, state_, crf, t_now), 1, err

        def cached_branch(op):
            x_, state_ = op
            crf_hat = cache_lib.predict(policy, state_, t_now)
            return (from_crf_fn(crf_hat, t_now), state_, 0,
                    jnp.zeros((), jnp.float32))

        if policy.kind == "teacache":
            rel = jnp.mean(jnp.abs(x - prev_x)) / jnp.maximum(
                jnp.mean(jnp.abs(prev_x)), 1e-6)
            acc = acc + rel.astype(jnp.float32)
            warm = state.n_valid < 1
            act = warm | (acc > policy.tea_threshold) | (i == 0)
            acc = jnp.where(act, 0.0, acc)
        elif policy.kind == "freqca_a":
            warm = state.n_valid < 3
            # projected error of the NEXT cached step ~ (since+1)·err_last
            projected = (since.astype(jnp.float32) + 1.0) * err_last
            act = warm | (projected > policy.tea_threshold)
        else:
            act = cache_lib.should_activate(policy, state, i)
        if policy.kind == "none":
            v, state, used, err_new = full_branch((x, state))
        else:
            v, state, used, err_new = jax.lax.cond(
                act, full_branch, cached_branch, (x, state))
        since = jnp.where(jnp.asarray(used, bool), 0, since + 1)
        err_last = jnp.where(jnp.asarray(used, bool), err_new, err_last)
        dt = (t_next - t_now).astype(x.dtype)
        x_new = x + dt * v.astype(x.dtype)
        out = (x_new if return_trajectory else (),
               jnp.asarray(used, jnp.int32))
        return (x_new, state, (acc, x, since, err_last)), out

    idx = jnp.arange(n_steps)
    (x, _, _), (traj, used) = jax.lax.scan(step, (x_init, state0, tea0),
                                           (idx, ts[:-1], ts[1:]))
    return SampleResult(x=x, n_full=jnp.sum(used),
                        trajectory=traj if return_trajectory else None)


def reference_features(full_fn: Callable, x_init: jnp.ndarray,
                       ts: jnp.ndarray):
    """Run the un-cached sampler, returning per-step (x, crf) trajectories.

    Used by the Fig-2 frequency analysis and Fig-4 MSE benchmarks.
    """
    def step(x, tt):
        t_now, t_next = tt
        v, crf = full_fn(x, t_now)
        x_next = x + (t_next - t_now).astype(x.dtype) * v.astype(x.dtype)
        return x_next, (x_next, crf)

    x, (xs, crfs) = jax.lax.scan(step, x_init, (ts[:-1], ts[1:]))
    return x, xs, crfs
