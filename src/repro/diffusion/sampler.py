"""Diffusion sampling loop, policy-agnostic with per-lane activation.

The whole sampler is one ``lax.scan`` over timesteps.  The cache policy
is a self-contained object from ``repro.core.policies`` (or a legacy
``CachePolicy`` spec, or a per-lane sequence of either — one policy per
batch lane), driven through the four-method bank protocol; the sampler
never dispatches on policy names.

Each step the bank's ``decide`` returns a per-lane activation mask:

* batch-uniform mask (single non-adaptive policy) — scalar ``lax.cond``
  between the full branch (denoiser forward + cache update) and the
  cached branch (CRF prediction + final layer only): the seed fast
  path, one compiled program, full skip-compute win;
* lane-varying mask (adaptive policies / mixed banks) — the full
  forward runs iff *any* lane activates (``lax.cond``), and each lane's
  velocity and cache state are selected per lane with ``jnp.where``, so
  a mixed generation+editing batch never shares one global activation
  decision.  A lane behaves exactly as it would alone in the batch.

The denoiser is abstract: ``full_fn(x, t) -> (velocity, crf)`` and
``from_crf_fn(crf, t) -> velocity``; both DiT and backbone-wrapped
assigned architectures plug in (repro.models.dit).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.policies import base as policy_base
from repro.core.policies import registry as policy_registry

PolicyArg = Union[object, Sequence[object]]   # Policy | spec | per-lane seq


class SampleResult(NamedTuple):
    x: jnp.ndarray                  # final latents
    n_full: jnp.ndarray             # [] — batch forwards (compute) count
    n_full_lanes: Optional[jnp.ndarray] = None   # [B] activated steps/lane
    trajectory: Optional[jnp.ndarray] = None
    # [B]-shaped realized-error report when any lane's policy consumes
    # error feedback (freqca_eb), else None
    feedback: Optional[policy_base.ErrorFeedback] = None


def sample(full_fn: Callable, from_crf_fn: Callable, x_init: jnp.ndarray,
           ts: jnp.ndarray, policy: PolicyArg,
           crf_shape: Tuple[int, ...], crf_dtype=jnp.float32,
           return_trajectory: bool = False) -> SampleResult:
    """Euler rectified-flow sampling from t=1 to t=0 under a cache policy.

    ts: [n_steps+1] decreasing times.  crf_shape: [B, *feat] shape of the
    CRF feature (needed to build the static cache state).  ``policy``
    may be a Policy object, a CachePolicy spec, or a per-lane sequence
    of them (len == batch) for mixed-policy batches.
    """
    n_steps = ts.shape[0] - 1
    batch = x_init.shape[0]
    feat_shape = tuple(crf_shape[1:])
    bank = policy_registry.bank(policy, batch)
    state0 = bank.init(feat_shape, crf_dtype,
                       latent_shape=x_init.shape[1:],
                       latent_dtype=x_init.dtype)

    def step(carry, inp):
        x, state = carry
        i, t_now, t_next = inp
        ctx = policy_base.StepContext(step_idx=i, t_now=t_now, x=x,
                                      batch=batch, feat_shape=feat_shape,
                                      crf_dtype=crf_dtype)
        state, mask = bank.decide(state, ctx)

        def full_branch(op):
            x_, state_ = op
            v_full, crf = full_fn(x_, t_now)
            if bank.uses_error_feedback:
                # score the prediction the cache WOULD have served for
                # this step (pre-update state) against the fresh CRF,
                # then feed it back after the push — the feedback loop
                # only costs ops for policies that opted in (static
                # flag), so everything else traces bit-identically
                err = bank.measure_error(state_, crf, ctx)
                state_ = bank.apply_update(state_, crf, ctx, mask)
                state_ = bank.observe(state_, err, ctx, mask)
            else:
                state_ = bank.apply_update(state_, crf, ctx, mask)
            if bank.scalar_decision:
                return v_full, state_
            # lanes that did not activate keep their own schedule: they
            # consume the cached prediction even though the batch paid
            # for a forward (quality decoupling across lanes)
            v_hat = from_crf_fn(bank.predict(state_, ctx), t_now)
            m = mask.reshape((batch,) + (1,) * (v_full.ndim - 1))
            return jnp.where(m, v_full, v_hat.astype(v_full.dtype)), state_

        def cached_branch(op):
            x_, state_ = op
            return from_crf_fn(bank.predict(state_, ctx), t_now), state_

        if bank.always_full:
            act = jnp.asarray(True)
            v, state = full_branch((x, state))
        else:
            act = mask[0] if bank.scalar_decision else jnp.any(mask)
            v, state = jax.lax.cond(act, full_branch, cached_branch,
                                    (x, state))
        dt = (t_next - t_now).astype(x.dtype)
        x_new = x + dt * v.astype(x.dtype)
        out = (x_new if return_trajectory else (),
               jnp.asarray(act, jnp.int32), mask.astype(jnp.int32))
        return (x_new, state), out

    idx = jnp.arange(n_steps)
    (x, state), (traj, fwd, used) = jax.lax.scan(step, (x_init, state0),
                                                 (idx, ts[:-1], ts[1:]))
    feedback = (bank.error_feedback(state)
                if bank.uses_error_feedback else None)
    return SampleResult(x=x, n_full=jnp.sum(fwd),
                        n_full_lanes=jnp.sum(used, axis=0),
                        trajectory=traj if return_trajectory else None,
                        feedback=feedback)


def reference_features(full_fn: Callable, x_init: jnp.ndarray,
                       ts: jnp.ndarray):
    """Run the un-cached sampler, returning per-step (x, crf) trajectories.

    Used by the Fig-2 frequency analysis and Fig-4 MSE benchmarks.
    """
    def step(x, tt):
        t_now, t_next = tt
        v, crf = full_fn(x, t_now)
        x_next = x + (t_next - t_now).astype(x.dtype) * v.astype(x.dtype)
        return x_next, (x_next, crf)

    x, (xs, crfs) = jax.lax.scan(step, x_init, (ts[:-1], ts[1:]))
    return x, xs, crfs
