"""AdamW + LR schedules + global-norm clipping (optax is not available
offline, so the optimizer is part of the substrate per the assignment).

State is a pytree shaped like the params (ZeRO-style sharding falls out
of sharding it with the same rules as the params).  ``moment_dtype``
lets >=100B configs keep Adam moments in bf16 to fit HBM (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mu_hat = mu_n / c1
        nu_hat = nu_n / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(dt), nu_n.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu, strict=True)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(mu=new_mu, nu=new_nu, step=step), {
        "grad_norm": gnorm, "lr": lr}
