"""Fused FreqCa cached-step kernel.

The cached step is pure memory traffic: read the low band + K high-band
history tensors, combine with K scalar Hermite weights, write ẑ.  A
naive implementation is K+1 separate elementwise kernels (2(K+1) HBM
passes); this kernel does it in ONE pass over [token x d_model] tiles —
4 reads + 1 write for the paper's K=3, putting the cached step at the
memory-roofline minimum (DESIGN.md §3).

The Hermite evaluation weights are computed host-side (they depend only
on the K cached timestamps and the query time — a (m+1)-vector) and
passed as a tiny operand broadcast to every tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hermite


def _fused_kernel(w_ref, low_ref, hist_ref, o_ref):
    """low [bs, bd]; hist [K, bs, bd]; w [K]; o = low + sum_k w_k hist_k."""
    acc = low_ref[...].astype(jnp.float32)
    k = hist_ref.shape[0]
    for i in range(k):                      # K is tiny & static: unrolled FMA
        acc += w_ref[i] * hist_ref[i].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def hermite_eval_weights(ts: jnp.ndarray, t_query, order: int) -> jnp.ndarray:
    """Weights w st. prediction = sum_k w_k · hist_k (least-squares fold).

    Alias of :func:`repro.core.hermite.eval_weights` — the shared
    normal-equation setup lives there so the folded kernel path and the
    explicit fit can never drift apart.
    """
    return hermite.eval_weights(ts, t_query, order)


def freqca_predict_fused(low: jnp.ndarray, high_hist: jnp.ndarray,
                         ts: jnp.ndarray, t_query, order: int,
                         block_s: int = 256, block_d: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """ẑ = low + Hermite(high_hist)(t_query), one fused pass.

    low: [B, S, D]; high_hist: [K, B, S, D]; ts: [K].
    """
    w = hermite_eval_weights(ts, t_query, order)
    kh, b, s, d = high_hist.shape
    bs = min(block_s, s)
    bd = min(block_d, d)
    assert s % bs == 0 and d % bd == 0, (s, d, bs, bd)
    grid = (s // bs, d // bd)

    def run_one(low2, hist2):  # [S, D], [K, S, D]
        return pl.pallas_call(
            _fused_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((kh,), lambda i, j: (0,)),
                pl.BlockSpec((bs, bd), lambda i, j: (i, j)),
                pl.BlockSpec((kh, bs, bd), lambda i, j: (0, i, j)),
            ],
            out_specs=pl.BlockSpec((bs, bd), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((s, d), low2.dtype),
            interpret=interpret,
        )(w, low2, hist2)

    return jax.vmap(run_one, in_axes=(0, 1))(low, high_hist)


# ---------------------------------------------------------------------------
# spectral cached step: synthesis matmul fused with the Hermite FMA
# ---------------------------------------------------------------------------

def _fused_spectral_kernel(w_ref, synth_ref, low_ref, hist_ref, o_ref):
    """synth [bs, m]; low [m, bd]; hist [K, bs, bd]; w [K].

    ẑ tile = synth·low + Σ_k w_k hist_k — the low band is synthesised
    from its m spectral rows on the MXU inside the same pass that FMAs
    the K high-band history tiles, so the cached step reads only
    K·S·D + m·D + S·m floats from HBM and writes S·D once."""
    acc = jnp.dot(synth_ref[...].astype(jnp.float32),
                  low_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    k = hist_ref.shape[0]
    for i in range(k):                      # K is tiny & static: unrolled FMA
        acc += w_ref[i] * hist_ref[i].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def freqca_predict_fused_spectral(low_spec: jnp.ndarray, synth: jnp.ndarray,
                                  high_hist: jnp.ndarray, w: jnp.ndarray,
                                  block_s: int = 256, block_d: int = 256,
                                  interpret: bool = True) -> jnp.ndarray:
    """ẑ = synthᵀ-reconstructed low band + per-lane Hermite(high), fused.

    low_spec: [B, m, D] spectral low-band coefficients (already combined
    across the low ring — order 0 is just the freshest entry);
    synth: [S, m] synthesis basis (``frequency.low_band_basis(S).T``);
    high_hist: [B, K, S, D]; w: [B, K] per-lane folded Hermite weights
    (lanes activate at different times, so each carries its own fold).
    """
    b, kh, s, d = high_hist.shape
    bs = min(block_s, s)
    bd = min(block_d, d)
    assert s % bs == 0 and d % bd == 0, (s, d, bs, bd)
    m = synth.shape[1]
    grid = (s // bs, d // bd)

    def run_one(w1, low1, hist1):  # [K], [m, D], [K, S, D]
        return pl.pallas_call(
            _fused_spectral_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((kh,), lambda i, j: (0,)),
                pl.BlockSpec((bs, m), lambda i, j: (i, 0)),
                pl.BlockSpec((m, bd), lambda i, j: (0, j)),
                pl.BlockSpec((kh, bs, bd), lambda i, j: (0, i, j)),
            ],
            out_specs=pl.BlockSpec((bs, bd), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((s, d), high_hist.dtype),
            interpret=interpret,
        )(w1, synth, low1, hist1)

    return jax.vmap(run_one)(w.astype(jnp.float32), low_spec, high_hist)
