"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import frequency, hermite
from repro.models import ssm


def token_basis_matmul_ref(basis: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[..., s, d] = basis @ x along the token axis."""
    return jnp.einsum("sk,bkd->bsd", basis.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)


def band_split_ref(x: jnp.ndarray, rho: float, method: str = "dct"):
    bands = frequency.decompose(x, rho, method, axis=-2)
    return bands.low, bands.high


def freqca_predict_ref(low: jnp.ndarray, high_hist: jnp.ndarray,
                       ts: jnp.ndarray, t_query, order: int) -> jnp.ndarray:
    high = hermite.predict(ts, high_hist, t_query, order)
    return (low.astype(jnp.float32)
            + high.astype(jnp.float32)).astype(low.dtype)


def band_split_spectral_ref(x: jnp.ndarray, rho: float,
                            method: str = "dct"):
    """(low_spec [B, m, D], high [B, S, D]) — the spectral split oracle
    (and the XLA dispatch path): two einsums against the low basis."""
    basis = frequency.low_band_basis(x.shape[-2], rho, method)
    xf = x.astype(jnp.float32)
    low_spec = jnp.einsum("ms,bsd->bmd", basis, xf)
    high = xf - jnp.einsum("ms,bmd->bsd", basis, low_spec)
    return low_spec.astype(x.dtype), high.astype(x.dtype)


def freqca_predict_spectral_ref(low_spec: jnp.ndarray, synth: jnp.ndarray,
                                high_hist: jnp.ndarray,
                                w: jnp.ndarray) -> jnp.ndarray:
    """ẑ = synth·low_spec + Σ_k w[b, k]·high_hist[b, k] (per lane)."""
    low = jnp.einsum("sm,bmd->bsd", synth.astype(jnp.float32),
                     low_spec.astype(jnp.float32))
    high = jnp.einsum("bk,bksd->bsd", w.astype(jnp.float32),
                      high_hist.astype(jnp.float32))
    return (low + high).astype(high_hist.dtype)


def ssd_chunked_ref(x, dt, A, B, C, chunk: int):
    """Delegates to the model's pure-jnp chunked SSD (itself validated
    against the naive per-token recurrence in tests)."""
    return ssm.ssd_chunked(x, dt, A, B, C, chunk)


def ssd_naive_ref(x, dt, A, B, C):
    """O(S) per-token recurrence — the ground-truth SSD semantics.

    x: [b, s, h, p]; dt: [b, s, h]; A: [h]; B, C: [b, s, n].
    """
    import jax
    f32 = jnp.float32
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        y, state = ssm.ssd_recurrent_step(x_t, dt_t, A, b_t, c_t, state)
        return state, y

    init = jnp.zeros((b, h, p, n), f32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    state, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
