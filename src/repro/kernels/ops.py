"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode
(the kernel body runs as traced jnp, validating the exact program the
TPU would run); on a real TPU backend set ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dct as dct_kernel
from repro.kernels import freqca_fused as fused_kernel
from repro.kernels import ssd_scan as ssd_kernel

INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_s", "block_d", "block_k"))
def dct_tokens(x: jnp.ndarray, block_s: int = 128, block_d: int = 128,
               block_k: int = 128) -> jnp.ndarray:
    """Orthonormal DCT-II along the token axis of [B, S, D]."""
    basis = dct_kernel.frequency.dct_basis(x.shape[-2])
    return dct_kernel.token_basis_matmul(basis, x, block_s, block_d, block_k,
                                         interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("rho", "method"))
def band_split(x: jnp.ndarray, rho: float = 0.0625, method: str = "dct"):
    """FreqCa band split (low, high) as one fused projection matmul."""
    return dct_kernel.band_split(x, rho, method, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("order",))
def freqca_predict(low: jnp.ndarray, high_hist: jnp.ndarray,
                   ts: jnp.ndarray, t_query, order: int = 2) -> jnp.ndarray:
    """Fused cached-step reconstruction: ẑ = low + Hermite(high)(t)."""
    return fused_kernel.freqca_predict_fused(low, high_hist, ts, t_query,
                                             order, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, B, C, chunk: int = 256):
    """Mamba2 SSD chunk scan."""
    return ssd_kernel.ssd_chunk_scan(x, dt, A, B, C, chunk,
                                     interpret=INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("q_per_kv", "causal", "window",
                                    "q_block", "kv_block"))
def flash(q, k, v, q_per_kv: int, causal: bool = True, window: int = 0,
          q_block: int = 128, kv_block: int = 128):
    """Flash attention (GQA) kernel."""
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, q_per_kv, causal=causal,
                              window=window, q_block=q_block,
                              kv_block=kv_block, interpret=INTERPRET)
