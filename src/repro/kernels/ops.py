"""Backend-dispatch layer for the Pallas kernel suite.

``REPRO_KERNELS=pallas|xla`` selects the implementation behind every op
here; unset, it defaults to ``pallas`` on TPU and ``xla`` elsewhere
(interpret-mode Pallas is correct but slow on CPU, so off-TPU the
pure-jnp paths win).  Consumers — ``core.frequency.decompose``,
``core.policies.base.ring_predict``, ``core.policies.freqca``,
``models.dit._joint_attention`` — route their hot paths through this
module so the cached step, the band split, and joint attention run the
fused kernels on TPU without forking any call sites.

Both the backend and interpret mode are read **lazily at call time**
(``backend()`` / ``interpret()``), never frozen at import, so a test
can flip ``REPRO_KERNELS`` between calls without reimporting; the
jitted implementations carry them as static arguments, which keys the
jit cache correctly across flips.  (Dispatch is resolved at trace time:
executables already compiled — e.g. a warmed serving engine — keep the
backend they were traced with.)
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import hermite
from repro.kernels import dct as dct_kernel
from repro.kernels import freqca_fused as fused_kernel
from repro.kernels import ref
from repro.kernels import ssd_scan as ssd_kernel


# ---------------------------------------------------------------------------
# backend selection (lazy — never frozen at import time)
# ---------------------------------------------------------------------------

def backend() -> str:
    """'pallas' | 'xla' — from ``REPRO_KERNELS``, else by jax backend."""
    env = os.environ.get("REPRO_KERNELS", "").strip().lower()
    if env in ("pallas", "xla"):
        return env
    if env:
        raise ValueError(
            f"REPRO_KERNELS must be 'pallas' or 'xla', got {env!r}")
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def use_pallas() -> bool:
    return backend() == "pallas"


def interpret() -> bool:
    """Pallas interpret mode: forced via ``REPRO_KERNELS_INTERPRET``,
    else on everywhere except a real TPU backend."""
    env = os.environ.get("REPRO_KERNELS_INTERPRET", "").strip().lower()
    if env in ("1", "true"):
        return True
    if env in ("0", "false"):
        return False
    if env:
        raise ValueError("REPRO_KERNELS_INTERPRET must be 0/false or "
                         f"1/true, got {env!r}")
    return jax.default_backend() != "tpu"


def __getattr__(name: str):
    # back-compat: ops.INTERPRET used to be a module constant frozen at
    # import; keep the attribute but compute it lazily
    if name == "INTERPRET":
        return interpret()
    raise AttributeError(name)


# ---------------------------------------------------------------------------
# kernel wrappers (jitted, backend/interpret as static args)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_s", "block_d", "block_k",
                                             "interpret_"))
def _dct_tokens(x, block_s, block_d, block_k, interpret_):
    basis = dct_kernel.frequency.dct_basis(x.shape[-2])
    return dct_kernel.token_basis_matmul(basis, x, block_s, block_d, block_k,
                                         interpret=interpret_)


def dct_tokens(x: jnp.ndarray, block_s: int = 128, block_d: int = 128,
               block_k: int = 128) -> jnp.ndarray:
    """Orthonormal DCT-II along the token axis of [B, S, D]."""
    return _dct_tokens(x, block_s, block_d, block_k, interpret())


@functools.partial(jax.jit, static_argnames=("rho", "method", "interpret_"))
def _band_split(x, rho, method, interpret_):
    return dct_kernel.band_split(x, rho, method, interpret=interpret_)


def band_split(x: jnp.ndarray, rho: float = 0.0625, method: str = "dct"):
    """FreqCa band split (low, high) as one fused projection matmul."""
    return _band_split(x, rho, method, interpret())


# non-divisible shapes fall back to the jnp path; the predicate lives
# next to the kernels' block defaults (kernels/dct.py)
_spectral_shapes_ok = dct_kernel.spectral_dispatch_ok


@functools.partial(jax.jit, static_argnames=("rho", "method", "interpret_"))
def _band_split_spectral_pallas(x, rho, method, interpret_):
    return dct_kernel.band_split_spectral(x, rho, method,
                                          interpret=interpret_)


@functools.partial(jax.jit, static_argnames=("rho", "method"))
def _band_split_spectral_xla(x, rho, method):
    return ref.band_split_spectral_ref(x, rho, method)


def band_split_spectral(x: jnp.ndarray, rho: float = 0.0625,
                        method: str = "dct"):
    """Spectral band split: ``(low_spec [B, m, D], high [B, S, D])``.

    The cache-facing op: the low band never materialises spatially —
    ``m = spectral_kept_bins(S, rho, method)`` coefficient rows are the
    stored representation (~``rho`` of the spatial footprint).
    """
    _, s, d = x.shape
    if use_pallas() and _spectral_shapes_ok(s, d):
        return _band_split_spectral_pallas(x, rho, method, interpret())
    return _band_split_spectral_xla(x, rho, method)


@functools.partial(jax.jit, static_argnames=("order", "interpret_"))
def _freqca_predict(low, high_hist, ts, t_query, order, interpret_):
    return fused_kernel.freqca_predict_fused(low, high_hist, ts, t_query,
                                             order, interpret=interpret_)


def freqca_predict(low: jnp.ndarray, high_hist: jnp.ndarray,
                   ts: jnp.ndarray, t_query, order: int = 2) -> jnp.ndarray:
    """Fused cached-step reconstruction: ẑ = low + Hermite(high)(t)."""
    return _freqca_predict(low, high_hist, ts, t_query, order, interpret())


@functools.partial(jax.jit, static_argnames=("interpret_",))
def _freqca_predict_spectral_pallas(low_spec, synth, high_hist, w,
                                    interpret_):
    return fused_kernel.freqca_predict_fused_spectral(
        low_spec, synth, high_hist, w, interpret=interpret_)


@jax.jit
def _freqca_predict_spectral_xla(low_spec, synth, high_hist, w):
    return ref.freqca_predict_spectral_ref(low_spec, synth, high_hist, w)


def freqca_predict_spectral(low_spec: jnp.ndarray, synth: jnp.ndarray,
                            high_hist: jnp.ndarray,
                            w: jnp.ndarray) -> jnp.ndarray:
    """Fused spectral cached step: synth·low_spec + Σ_k w[:, k]·high_k.

    low_spec [B, m, D]; synth [S, m]; high_hist [B, K, S, D];
    w [B, K] per-lane folded Hermite weights (``hermite_weights``).
    """
    _, _, s, d = high_hist.shape
    if use_pallas() and _spectral_shapes_ok(s, d):
        return _freqca_predict_spectral_pallas(low_spec, synth, high_hist,
                                               w, interpret())
    return _freqca_predict_spectral_xla(low_spec, synth, high_hist, w)


@functools.partial(jax.jit, static_argnames=("order",))
def hermite_weights(ts: jnp.ndarray, t_query, order: int) -> jnp.ndarray:
    """Per-lane folded Hermite evaluation weights: [B, K] from ts [B, K].

    The host-side half of the fused cached step — the normal-equation
    solve collapses to K scalars per lane (``hermite.eval_weights``),
    so prediction is one FMA pass regardless of backend.
    """
    return jax.vmap(lambda t: hermite.eval_weights(t, t_query, order))(ts)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret_"))
def _ssd(x, dt, A, B, C, chunk, interpret_):
    return ssd_kernel.ssd_chunk_scan(x, dt, A, B, C, chunk,
                                     interpret=interpret_)


def ssd(x, dt, A, B, C, chunk: int = 256):
    """Mamba2 SSD chunk scan."""
    return _ssd(x, dt, A, B, C, chunk, interpret())


@functools.partial(jax.jit,
                   static_argnames=("q_per_kv", "causal", "window",
                                    "q_block", "kv_block", "interpret_"))
def _flash(q, k, v, q_per_kv, causal, window, q_block, kv_block,
           interpret_):
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, q_per_kv, causal=causal,
                              window=window, q_block=q_block,
                              kv_block=kv_block, interpret=interpret_)


def flash(q, k, v, q_per_kv: int, causal: bool = True, window: int = 0,
          q_block: int = 128, kv_block: int = 128):
    """Flash attention (GQA) kernel."""
    return _flash(q, k, v, q_per_kv, causal, window, q_block, kv_block,
                  interpret())
