"""Flash attention (GQA) Pallas kernel — the prefill/train hot spot.

Grid: (batch x kv_heads x q_groups, q blocks, kv blocks) with the kv
dimension SEQUENTIAL; the online-softmax state (acc, running max m,
normaliser l) lives in VMEM scratch across kv steps and the output tile
is written once on the last step — the TPU-native version of the
jnp blockwise path in ``models/attention.blockwise_sdpa`` (its oracle).

This is what the roofline's "memory term is an upper bound" note refers
to (EXPERIMENTS.md §Roofline): the XLA-level blockwise path materialises
[qb x kb] logits tiles at fusion boundaries, while this kernel keeps
them in VMEM/VREGs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def dispatch_ok(s: int, q_block: int = 128, kv_block: int = 128) -> bool:
    """Self-attention shapes ``flash_attention``'s default tiling
    accepts (it asserts ``s % qb == 0`` at trace time) — dispatch
    layers pre-check here so the predicate can't drift from the block
    defaults."""
    return s % min(q_block, s) == 0 and s % min(kv_block, s) == 0


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, causal: bool, window: int, kb: int, nk: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [qb, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [kb, hd]
    v = v_ref[0, 0].astype(jnp.float32)          # [kb, hd]
    qb = q.shape[0]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = jnp.ones((qb, kb), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, q_per_kv: int, causal: bool = True,
                    window: int = 0, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = True):
    """q: [B, S, Hq, hd]; k, v: [B, T, Hkv, hd] -> [B, S, Hq, hd]."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    qb = min(q_block, s)
    kb = min(kv_block, t)
    assert s % qb == 0 and t % kb == 0, (s, t, qb, kb)
    nq, nk = s // qb, t // kb
    g = q_per_kv
    scale = 1.0 / math.sqrt(hd)

    # layout: fold (b, kv_head, group) into one parallel axis; repeat K/V
    # per group via index mapping (no materialised copy)
    qg = q.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, nq, qb, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, nk, kb, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, nk, kb, hd)

    import functools
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               kb=kb, nk=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qb, hd), lambda i, qi, ki: (i, qi, 0, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda i, qi, ki: (i // g, ki, 0, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda i, qi, ki: (i // g, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, hd),
                               lambda i, qi, ki: (i, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv * g, nq, qb, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, hd), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, hq, hd)
