"""Mamba2 SSD (state-space duality) chunk-scan Pallas kernel.

TPU-native layout of the SSD algorithm [arXiv:2405.21060]: the grid is
(batch x heads, chunks) with the chunk dimension SEQUENTIAL
(``dimension_semantics=("parallel", "arbitrary")`` on real TPU); the
running state [d_state x head_dim] lives in a VMEM scratch accumulator
across chunk steps, so the recurrence never round-trips HBM.  Within a
chunk everything is dense [Q x Q] / [Q x N] matmuls on the MXU — that
is the whole point of SSD: the sequential part is O(S/Q) cheap state
updates, the parallel part is MXU-shaped.

Per chunk (A < 0 per head, a = exp(cumsum(dt*A))):
  y_diag = ((C Bᵀ) ∘ L) (dt ∘ x)      L_ij = a_i / a_j  (j <= i)
  y_off  = a ∘ (C · state)
  state ← a_Q · state + Σ_j (a_Q / a_j) dt_j B_jᵀ x_j
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_CLIP = -60.0  # exp underflow guard for cumulative decay


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    f32 = jnp.float32
    x = x_ref[0, 0].astype(f32)                  # [Q, P]
    dt = dt_ref[0, 0].astype(f32)                # [Q]
    b = b_ref[0, 0].astype(f32)                  # [Q, N]
    c = c_ref[0, 0].astype(f32)                  # [Q, N]
    a_h = a_ref[0].astype(f32)                # scalar A (negative)

    da = dt * a_h                             # [Q]
    cum = jnp.cumsum(da)                      # [Q]
    # intra-chunk: L_ij = exp(cum_i - cum_j) for j <= i
    q = x.shape[0]
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(col <= row, jnp.exp(jnp.maximum(diff, NEG_CLIP)), 0.0)
    scores = jnp.dot(c, b.T, preferred_element_type=f32) * l_mat  # [Q, Q]
    y = jnp.dot(scores * dt[None, :], x, preferred_element_type=f32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                    # [N, P]
    decay_in = jnp.exp(jnp.maximum(cum, NEG_CLIP))[:, None]       # [Q, 1]
    y += decay_in * jnp.dot(c, state, preferred_element_type=f32)

    # state update
    decay_out = jnp.exp(jnp.maximum(cum[-1] - cum, NEG_CLIP))     # [Q]
    weighted_b = b * (dt * decay_out)[:, None]                    # [Q, N]
    state_ref[...] = (jnp.exp(jnp.maximum(cum[-1], NEG_CLIP)) * state
                      + jnp.dot(weighted_b.T, x,
                                preferred_element_type=f32))
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_chunk_scan(x, dt, A, B, C, chunk: int = 256,
                   interpret: bool = True):
    """Pallas SSD scan.  x: [b, s, h, p]; dt: [b, s, h]; A: [h];
    B, C: [b, s, n].  Returns y: [b, s, h, p] (no D-skip / gating —
    those stay in the surrounding jnp block)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    # layout: merge (b, h) into the parallel grid axis
    xg = x.transpose(0, 2, 1, 3).reshape(b * h, nc, q, p)
    dtg = dt.transpose(0, 2, 1).reshape(b * h, nc, q)
    bg = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, nc, q, n)
    cg = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, nc, q, n)
    ag = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)

    y = pl.pallas_call(
        _ssd_kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda i, c: (i,)),            # A
            pl.BlockSpec((1, 1, q, p), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, c: (i, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nc, q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(ag, xg, dtg, bg, cg)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
