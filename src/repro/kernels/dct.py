"""Tiled token-axis basis matmul — DCT-II / IDCT / fused band-split on MXU.

GPU FreqCa calls cuFFT; TPUs have no FFT unit but a DCT-II along the
token axis is ``Y = C @ X`` with a fixed S x S basis — a dense matmul
that maps straight onto the 128x128 MXU (DESIGN.md §3).  Because
FreqCa's low-pass path is ``low = C^T · diag(mask) · C · x``, the whole
band-split collapses into ONE basis matmul with the precomputed
projection matrix ``L = C^T diag(m) C`` — ``band_split_basis`` below.

Kernel: classic 3-loop tiled matmul, K innermost in the grid with
accumulation in the output tile (revisited across the K grid dim), all
tiles MXU-aligned (multiples of 128 for real shapes; smaller shapes run
single-tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import numpy as np

from repro.core import frequency


def _matmul_kernel(basis_ref, x_ref, o_ref):
    """Grid (i over S-tiles, j over D-tiles, k over K-tiles); K innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        basis_ref[...], x_ref[...],
        preferred_element_type=o_ref.dtype)


def token_basis_matmul(basis: jnp.ndarray, x: jnp.ndarray,
                       block_s: int = 128, block_d: int = 128,
                       block_k: int = 128, interpret: bool = True):
    """y[..., s, d] = sum_k basis[s, k] * x[..., k, d].

    basis: [S, S]; x: [B, S, D].  Tiles are VMEM-resident:
    (block_s x block_k) basis + (block_k x block_d) x + accumulator.
    """
    b, s, d = x.shape
    bs = min(block_s, s)
    bd = min(block_d, d)
    bk = min(block_k, s)
    assert s % bs == 0 and d % bd == 0 and s % bk == 0, (s, d, bs, bd, bk)
    grid = (s // bs, d // bd, s // bk)

    def run_one(x2):  # [S, D]
        return pl.pallas_call(
            _matmul_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bs, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((bs, bd), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
            interpret=interpret,
        )(basis.astype(jnp.float32), x2.astype(jnp.float32))

    y = jax.vmap(run_one)(x)
    return y.astype(x.dtype)


@functools.lru_cache(maxsize=16)
def _band_split_basis_np(s: int, rho: float, method: str):
    """Low-pass projection L = C^T diag(mask) C (idempotent, symmetric).

    The kept bins come from ``frequency.low_pass_mask_np`` — the single
    source of the band-width rounding rule."""
    if method == "dct":
        c = frequency._dct_basis_np(s)
        mask = frequency.low_pass_mask_np(s, rho, "dct")
        return (c.T * mask.astype(np.float64)) @ c
    # fft: real low-pass projection is circulant; build from the mask
    mask = frequency.low_pass_mask_np(s, rho, "fft")
    f = np.fft.fft(np.eye(s), axis=0)
    finv = np.fft.ifft(np.diag(mask.astype(np.float64)) @ f, axis=0)
    return np.real(finv)


def band_split_basis(s: int, rho: float, method: str = "dct",
                     dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(_band_split_basis_np(s, rho, method), dtype)


def band_split_dispatch_ok(s: int, d: int, block: int = 128) -> bool:
    """Shapes ``token_basis_matmul``'s default tiling accepts — keep in
    sync with its ``block_*=128`` defaults (it asserts divisibility at
    trace time, so dispatch layers must pre-check here)."""
    return s % min(block, s) == 0 and d % min(block, d) == 0


def spectral_dispatch_ok(s: int, d: int, block: int = 256) -> bool:
    """Shapes the spectral kernels' default tiling accepts
    (``band_split_spectral`` block_d and
    ``freqca_fused.freqca_predict_fused_spectral`` block_s/block_d are
    all 256)."""
    return d % min(block, d) == 0 and s % min(block, s) == 0


def band_split(x: jnp.ndarray, rho: float, method: str = "dct",
               interpret: bool = True):
    """FreqCa band split as a single tiled matmul: returns (low, high)."""
    s = x.shape[-2]
    basis = band_split_basis(s, rho, method)
    low = token_basis_matmul(basis, x, interpret=interpret)
    return low, x - low


# ---------------------------------------------------------------------------
# spectral band split: (low coefficients, spatial high) in one pass
# ---------------------------------------------------------------------------

def _band_split_spectral_kernel(basis_ref, x_ref, low_ref, high_ref):
    """basis [m, S]; x [S, bd] -> low = B·x [m, bd], high = x − Bᵀ·low.

    Both outputs come out of ONE read of the x tile: the analysis
    matmul produces the compressed low-band coefficients directly (no
    S×S projection matmul, no spatial low band ever materialised) and
    the synthesis-transpose matmul immediately yields the high
    residual."""
    x = x_ref[...].astype(jnp.float32)
    b = basis_ref[...].astype(jnp.float32)
    low = jnp.dot(b, x, preferred_element_type=jnp.float32)
    low_ref[...] = low.astype(low_ref.dtype)
    recon = jnp.dot(b.T, low, preferred_element_type=jnp.float32)
    high_ref[...] = (x - recon).astype(high_ref.dtype)


def band_split_spectral(x: jnp.ndarray, rho: float, method: str = "dct",
                        block_d: int = 256, interpret: bool = True):
    """Fused spectral band split: ``(low_spec [B, m, D], high [B, S, D])``.

    ``m = frequency.spectral_kept_bins(S, rho, method)`` — the low band
    lives in the frequency domain at a ``rho`` fraction of the spatial
    footprint (the SpectralCache representation).  The token axis is
    VMEM-resident per tile (S·block_d floats), so the grid runs over D
    tiles only; ``low + high`` reconstruction means
    ``Bᵀ·low_spec + high == x`` to float round-off.
    """
    _, s, d = x.shape
    basis = frequency.low_band_basis(s, rho, method)
    m = basis.shape[0]
    bd = min(block_d, d)
    assert d % bd == 0, (d, bd)
    grid = (d // bd,)

    def run_one(x2):  # [S, D]
        return pl.pallas_call(
            _band_split_spectral_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((m, s), lambda j: (0, 0)),
                pl.BlockSpec((s, bd), lambda j: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec((m, bd), lambda j: (0, j)),
                pl.BlockSpec((s, bd), lambda j: (0, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((m, d), x.dtype),
                jax.ShapeDtypeStruct((s, d), x.dtype),
            ],
            interpret=interpret,
        )(basis, x2)

    low, high = jax.vmap(run_one)(x)
    return low, high
