"""Architecture registry (``--arch <id>``) + assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Dict, Union

from repro.configs import base
from repro.configs.base import DiTConfig, ModelConfig, MoEConfig, SSMConfig

from repro.configs import (command_r_plus_104b, deepseek_coder_33b, dit_small,
                           flux1_dev, granite_moe_3b, jamba_15_large,
                           llama3_405b, llava_next_34b, mamba2_370m,
                           phi35_moe_42b, seamless_m4t_medium, yi_9b)

_MODULES = [mamba2_370m, deepseek_coder_33b, seamless_m4t_medium,
            phi35_moe_42b, granite_moe_3b, llama3_405b, yi_9b,
            jamba_15_large, command_r_plus_104b, llava_next_34b,
            dit_small, flux1_dev]

REGISTRY: Dict[str, Union[ModelConfig, DiTConfig]] = {
    m.CONFIG.arch_id: m.CONFIG for m in _MODULES
}

# the ten assigned (architecture x shape) targets
ASSIGNED = [
    "mamba2-370m", "deepseek-coder-33b", "seamless-m4t-medium",
    "phi3.5-moe-42b-a6.6b", "granite-moe-3b-a800m", "llama3-405b",
    "yi-9b", "jamba-1.5-large-398b", "command-r-plus-104b",
    "llava-next-34b",
]

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32,
                    "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128,
                   "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

# window used for the sliding-window carve-out at long_500k on pure
# full-attention architectures (DESIGN.md §4)
LONG_CONTEXT_WINDOW = 8192


def get_config(arch_id: str):
    return REGISTRY[arch_id]


def list_archs():
    return list(REGISTRY)


def needs_sliding_window(cfg: ModelConfig, shape_name: str) -> bool:
    """True when this (arch, shape) runs the sliding-window variant."""
    if shape_name != "long_500k":
        return False
    # SSM state is O(1); hybrid keeps its sparse 1:7 attention full.
    return cfg.family not in ("ssm", "hybrid")


def for_shape(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Config variant actually lowered for a given input shape."""
    if isinstance(cfg, DiTConfig):
        return cfg
    updates = {}
    if needs_sliding_window(cfg, shape_name):
        updates["sliding_window"] = LONG_CONTEXT_WINDOW
    if INPUT_SHAPES[shape_name]["kind"] != "train":
        updates["remat"] = False
    return dataclasses.replace(cfg, **updates) if updates else cfg


def reduced(cfg):
    """CPU-runnable smoke variant of the same family (assignment: 2 layers,
    d_model <= 512, <= 4 experts)."""
    if isinstance(cfg, DiTConfig):
        return dataclasses.replace(
            cfg, n_layers=2, n_double=min(cfg.n_double, 1), d_model=64,
            n_heads=4, d_ff=128, text_dim=min(cfg.text_dim, 32),
            n_text_tokens=min(cfg.n_text_tokens, 8), dtype="float32")
    n_layers = 2 if cfg.family != "hybrid" else cfg.attn_every
    d_model = 128
    head_dim = 32
    n_heads = d_model // head_dim
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=4,
                                  top_k=min(cfg.moe.top_k, 2))
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk=16)
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=max(1, n_heads // 2), d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512, head_dim=head_dim, moe=moe, ssm=ssm,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_prefix_tokens=16 if cfg.n_prefix_tokens else 0,
        sliding_window=0, dtype="float32", remat=False)
