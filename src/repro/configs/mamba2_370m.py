"""Mamba2-370m — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab_size=50280, head_dim=64,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    source="SSD (state-space duality) [arXiv:2405.21060]",
)
