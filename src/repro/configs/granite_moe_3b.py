"""Granite-MoE 3B (800M active) — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, head_dim=64,
    moe=MoEConfig(n_experts=40, top_k=8, every=1),
    tie_embeddings=True,
    source="40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]",
)
