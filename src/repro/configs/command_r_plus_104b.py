"""Command-R+ 104B — dense GQA, no-bias, 256k vocab
[hf:CohereForAI/c4ai-command-r-plus family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab_size=256000, head_dim=128, use_bias=False,
    source="GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]",
)
