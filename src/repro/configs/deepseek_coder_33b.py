"""DeepSeek-Coder-33B — llama-arch dense GQA [arXiv:2401.14196]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab_size=32256, head_dim=128, rope_theta=100000.0,
    source="llama-arch [arXiv:2401.14196]",
)
