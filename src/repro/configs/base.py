"""Config dataclasses shared by every architecture.

One ``ModelConfig`` covers all assigned families (dense / moe / ssm /
hybrid / audio enc-dec / vlm); ``DiTConfig`` covers the paper's own
diffusion-transformer denoisers.  Configs are plain frozen dataclasses so
they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # Apply an MoE FFN every `every` layers (1 = all layers, 2 = alternating).
    every: int = 1
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    # dispatch implementation: "einsum" (GShard one-hot matmul, the
    # baseline) or "gather" (slot-indexed gather/scatter, §Perf)
    impl: str = "einsum"
    # pad the expert count (never-routed zero-prob experts) so the
    # expert dim divides the TP axis -> expert parallelism instead of
    # d_ff-sharded experts with per-expert all-reduces (§Perf)
    padded_experts: int = 0

    @property
    def e_total(self) -> int:
        return max(self.n_experts, self.padded_experts)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one attention layer per `attn_every` layers (Jamba 1:7 -> 8).
    attn_every: int = 0
    # encoder-decoder (audio): encoder layer count; encoder consumes
    # precomputed frame embeddings from the (stubbed) modality frontend.
    is_encdec: bool = False
    n_enc_layers: int = 0
    # vlm: number of prefix embedding tokens supplied by the (stubbed)
    # vision frontend (anyres tiling already applied upstream).
    n_prefix_tokens: int = 0
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 500000.0
    use_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    source: str = ""                 # citation for the config

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        ssm = self.ssm or SSMConfig()
        return ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        ssm = self.ssm or SSMConfig()
        return self.d_inner // ssm.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence of per-layer block kinds ('attn'|'ssm') of length n_layers."""
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.family == "hybrid" and self.attn_every > 0:
            kinds = []
            for i in range(self.n_layers):
                # one attention layer at the end of every group of attn_every
                kinds.append("attn" if (i % self.attn_every) == self.attn_every - 1 else "ssm")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None or self.moe.n_experts == 0:
            return False
        return (layer_idx % self.moe.every) == (self.moe.every - 1)


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    """Diffusion-transformer denoiser (the paper's model family).

    ``backbone`` may name an assigned ModelConfig arch to wrap as a
    denoiser (AdaLN time conditioning around its residual stack) — this is
    how FreqCa exercises the assigned architectures (DESIGN.md §4).
    """
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    patch_size: int = 2
    in_channels: int = 4
    # FLUX-like MMDiT: n_double joint (text+image dual-stream) blocks then
    # n_layers single-stream blocks. n_double == 0 -> plain DiT.
    n_double: int = 0
    text_dim: int = 0
    n_text_tokens: int = 0
    time_embed_dim: int = 256
    norm_eps: float = 1e-6
    dtype: str = "float32"
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads
