"""SeamlessM4T-medium — enc-dec multimodal speech backbone [arXiv:2308.11596].

The audio frontend (mel + conv feature extractor) is a stub per the
assignment; the encoder consumes precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=256206, head_dim=64,
    is_encdec=True, n_enc_layers=12,
    source="enc-dec, multimodal [arXiv:2308.11596]",
)
