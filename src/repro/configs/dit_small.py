"""CPU-scale DiT used for the paper-claims validation experiments."""
from repro.configs.base import DiTConfig

CONFIG = DiTConfig(
    arch_id="dit-small", n_layers=8, d_model=128, n_heads=8, d_ff=512,
    patch_size=2, in_channels=4, dtype="float32",
    source="in-repo small DiT (paper-claims validation at CPU scale)",
)
