"""FLUX.1-dev-like MMDiT — the paper's primary model [Labs 2024].

19 dual-stream (image+text) blocks + 38 single-stream blocks, d=3072,
16-channel latents — the FreqCa paper's L=57 cached-feature count.
Weights are not available offline; this config exists so the dry-run
lowers the paper's own architecture on the production mesh.
"""
from repro.configs.base import DiTConfig

CONFIG = DiTConfig(
    arch_id="flux1-dev", n_layers=38, n_double=19, d_model=3072,
    n_heads=24, d_ff=12288, patch_size=2, in_channels=16,
    text_dim=4096, n_text_tokens=512, dtype="bfloat16",
    source="FLUX.1-dev [github.com/black-forest-labs/flux]",
)
