"""Jamba-1.5-Large 398B — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

72 layers = 9 groups of (7 Mamba2 + 1 attention); MoE FFN on every other
layer (the Jamba cadence).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536, head_dim=128, attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=128, head_dim=128, expand=2, chunk=256),
    source="Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887]",
)
