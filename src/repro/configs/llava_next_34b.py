"""LLaVA-NeXT 34B — VLM language decoder; anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf family].

The vision frontend (SigLIP/ViT + projector, anyres tiling) is a stub
per the assignment: ``input_specs`` provides 2880 precomputed patch
embeddings (576 base + 4 tiles x 576) prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128, n_prefix_tokens=2880,
    source="anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)
