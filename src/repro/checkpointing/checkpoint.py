"""Pytree checkpointing: flat npz payload + json tree metadata.

No orbax offline; this covers save/restore for params, optimizer state
and data-iterator step with atomic rename semantics.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree, name: str = "state") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {"step": step,
            "keys": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                     for k, v in arrays.items()}}
    path = os.path.join(directory, f"{name}_{step:08d}")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, path + ".npz")
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str, name: str = "state") -> int:
    if not os.path.isdir(directory):
        return -1
    steps = [int(f[len(name) + 1:-5]) for f in os.listdir(directory)
             if f.startswith(name + "_") and f.endswith(".json")]
    return max(steps) if steps else -1


def restore(directory: str, step: int, like_tree, name: str = "state"):
    """Restore into the structure of ``like_tree``."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten_with_paths(like_tree)
    restored = {}
    for k, like in flat_like.items():
        arr = jnp.asarray(data[k])
        assert arr.shape == tuple(np.shape(like)), (k, arr.shape)
        restored[k] = arr.astype(like.dtype if hasattr(like, "dtype")
                                 else arr.dtype)
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    keys = list(_flatten_with_paths(like_tree).keys())
    return jax.tree_util.tree_unflatten(
        treedef, [restored[k] for k in keys])
