"""Request queue + bucketed batch formation for the diffusion engine.

The seed engine padded every batch to ``max_batch`` — a single request
paid full-batch latency.  The scheduler instead quantises batch sizes to
a small ladder of *bucket signatures* (powers of two up to
``max_batch``), so the engine compiles one sampler executable per bucket
and a lone request runs in the batch-1 program.

Batch formation is deadline/age-based: a batch is cut when the queue
can fill the largest bucket, when the oldest request has waited
``max_wait_s``, or when a per-request deadline is about to lapse.
Deadline-lapsed requests are *promoted* into the cut batch wherever
they sit in the queue (otherwise the batch is the stable FIFO prefix),
so a lapsed request can never be starved behind ``max_batch`` younger
ones.  ``flush=True`` cuts whatever is queued immediately (drain mode —
the seed engine's behaviour).

The queue is guarded by a condition variable (``cv``): ``submit`` /
``form_batch`` / ``ready`` are safe to call from any thread, submitters
wake anyone waiting on ``cv``, and ``seconds_until_ready`` tells a
worker exactly how long it may sleep before age or deadline pressure
would cut a batch — so the async engine blocks on wakeups instead of
sleep-polling.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, NamedTuple, Optional


@dataclasses.dataclass
class DiffusionRequest:
    request_id: int
    seed: int
    # optional conditioning (e.g. reference latents for editing)
    init_latents: Optional[object] = None
    edit_strength: float = 0.0
    # per-request cache policy (CachePolicy spec or Policy object);
    # None -> the engine's default.  Requests with different policies
    # share a batch lane-by-lane (per-lane activation masks).
    policy: Optional[object] = None
    # serving QoS: cut a batch early rather than let this lapse
    deadline_s: Optional[float] = None
    # accounting (stamped by Scheduler.submit)
    submit_time: float = 0.0


class BatchPlan(NamedTuple):
    requests: List[DiffusionRequest]
    bucket: int          # padded batch signature the engine will run
    formed_at: float     # scheduler clock when the batch was cut

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def occupancy(self) -> float:
        return self.n_real / max(self.bucket, 1)

    def lane_policies(self, default) -> List[object]:
        """Per-lane policy assignment; padded lanes reuse the first real
        lane's policy, so a uniform batch keeps one signature per bucket
        (the warmed ladder) and scheduled pads activate only on steps the
        real lanes already paid for — never forcing extra forwards of
        their own."""
        lanes = [r.policy if r.policy is not None else default
                 for r in self.requests]
        pad = lanes[0] if lanes else default
        lanes += [pad] * (self.bucket - self.n_real)
        return lanes


def bucket_sizes(max_batch: int) -> List[int]:
    """Powers of two up to ``max_batch`` (always including max_batch)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return sizes


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest bucket signature that fits ``n`` requests."""
    if n < 1:
        raise ValueError(f"need at least one request, got {n}")
    if n > max_batch:
        raise ValueError(f"{n} requests exceed max_batch={max_batch}")
    for b in bucket_sizes(max_batch):
        if b >= n:
            return b
    return max_batch


class Scheduler:
    """FIFO request queue with age/deadline-triggered batch cutting.

    Thread-safe: all queue access happens under ``cv`` (a reentrant
    condition variable), and every ``submit`` notifies waiters.
    """

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.05,
                 pad_to_max: bool = False, clock=time.monotonic):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.pad_to_max = pad_to_max  # seed-compatible fixed signature
        self.clock = clock
        self.queue: List[DiffusionRequest] = []
        self.submitted = 0
        self.cv = threading.Condition(threading.RLock())

    def __len__(self) -> int:
        with self.cv:
            return len(self.queue)

    @property
    def depth(self) -> int:
        return len(self)

    def submit(self, req: DiffusionRequest,
               now: Optional[float] = None) -> None:
        with self.cv:
            req.submit_time = self.clock() if now is None else now
            self.queue.append(req)
            self.submitted += 1
            self.cv.notify_all()

    def _lapsed(self, now: float) -> List[int]:
        """Queue indices whose deadline has already passed."""
        return [i for i, r in enumerate(self.queue)
                if r.deadline_s is not None
                and now - r.submit_time >= r.deadline_s]

    def _deadline_pressure(self, now: float) -> bool:
        return bool(self._lapsed(now))

    def ready(self, now: Optional[float] = None) -> bool:
        """Would ``form_batch`` cut a batch right now (without flushing)?"""
        with self.cv:
            if not self.queue:
                return False
            now = self.clock() if now is None else now
            if len(self.queue) >= self.max_batch:
                return True
            oldest_age = now - self.queue[0].submit_time
            return (oldest_age >= self.max_wait_s
                    or self._deadline_pressure(now))

    def seconds_until_ready(self, now: Optional[float] = None
                            ) -> Optional[float]:
        """How long until age/deadline pressure would cut a batch.

        Returns ``None`` for an empty queue (nothing to wait for — a
        submit will notify ``cv``), ``0.0`` if a batch is ready now, else
        the soonest of (oldest request hitting ``max_wait_s``, earliest
        deadline lapsing).  A worker can ``cv.wait(...)`` exactly this
        long instead of sleep-polling.
        """
        with self.cv:
            if not self.queue:
                return None
            now = self.clock() if now is None else now
            if self.ready(now):
                return 0.0
            until = self.max_wait_s - (now - self.queue[0].submit_time)
            for r in self.queue:
                if r.deadline_s is not None:
                    until = min(until,
                                r.deadline_s - (now - r.submit_time))
            return max(until, 0.0)

    def form_batch(self, now: Optional[float] = None,
                   flush: bool = False) -> Optional[BatchPlan]:
        """Cut the next batch, or None if nothing is ready yet.

        Deadline-lapsed requests are promoted into the cut wherever they
        sit in the queue (a lapsed request beyond position ``max_batch``
        used to trigger the cut yet be excluded from it — and could lapse
        indefinitely under sustained load); the remaining slots are the
        FIFO prefix, and the batch keeps stable FIFO order overall.
        """
        with self.cv:
            now = self.clock() if now is None else now
            if not self.queue or not (flush or self.ready(now)):
                return None
            take = min(len(self.queue), self.max_batch)
            picked = self._lapsed(now)[:take]
            picked_set = set(picked)
            i = 0
            while len(picked) < take:
                if i not in picked_set:
                    picked.append(i)
                    picked_set.add(i)
                i += 1
            reqs = [self.queue[i] for i in sorted(picked)]  # stable FIFO
            self.queue = [r for i, r in enumerate(self.queue)
                          if i not in picked_set]
            bucket = (self.max_batch if self.pad_to_max
                      else bucket_for(take, self.max_batch))
            return BatchPlan(requests=reqs, bucket=bucket, formed_at=now)
