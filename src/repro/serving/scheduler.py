"""Request queue + bucketed batch formation for the diffusion engine.

The seed engine padded every batch to ``max_batch`` — a single request
paid full-batch latency.  The scheduler instead quantises batch sizes to
a small ladder of *bucket signatures* (powers of two up to
``max_batch``), so the engine compiles one sampler executable per bucket
and a lone request runs in the batch-1 program.

Batch formation is deadline/age-based: a batch is cut when the queue
can fill the largest bucket, when the oldest request has waited
``max_wait_s``, or when a per-request deadline is about to lapse.
Deadline-lapsed requests are *promoted* into the cut batch wherever
they sit in the queue (otherwise the batch is the stable FIFO prefix),
so a lapsed request can never be starved behind ``max_batch`` younger
ones.  ``flush=True`` cuts whatever is queued immediately (drain mode —
the seed engine's behaviour).

With ``group_policies=True`` the former partitions the queue into
**compatibility groups** (``Policy.compatibility_key()``: identical
resolved policies, or static-schedule families whose activation masks
coincide — e.g. ``fora(interval=1)`` / ``none``) and every cut batch is
policy-homogeneous.  This caps the compiled-signature count at
O(groups x buckets) instead of one signature per lane-policy *mix*
(family cuts that mix distinct member values add one signature per
policy *composition* — lane order is canonicalized at cut time so
arrival interleaving never mints a new one), and static-schedule lanes
stop paying for adaptive lanes' activations (the sampler runs a full
forward whenever any lane in the batch activates).
Group choice per cut: (1) a lapsed deadline wins — the most-overdue
request's group is cut with its lapsed members promoted; (2) age
pressure (and ``flush``) cuts the group of the oldest request overall,
so a rare policy is served the moment its request heads the queue and
can never be starved by a busier group; (3) a full bucket alone cuts
the full group with the earliest-submitted member.  Within the chosen
group the batch is the lapsed members plus the FIFO prefix, in stable
FIFO order — exactly the ungrouped rule applied to the group.

Multi-resolution serving folds a canonical **shape key** —
``(latent_shape, crf_shape)`` — into the cut key *unconditionally*:
mixed-shape lanes cannot share one executable, so every cut is
shape-pure in any mode, and under grouping the cut key is
(shape, compatibility group).  ``submit`` validates each request's
declared shape against the deployment's shape ladder and raises
``ShapeMismatchError`` at the API boundary instead of failing deep
inside the jitted executable.

The queue is guarded by a condition variable (``cv``): ``submit`` /
``form_batch`` / ``ready`` are safe to call from any thread, submitters
wake anyone waiting on ``cv``, and ``seconds_until_ready`` tells a
worker exactly how long it may sleep before age or deadline pressure
would cut a batch — so the async engine blocks on wakeups instead of
sleep-polling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, NamedTuple, Optional, Tuple

from repro.analysis.runtime import make_condition


class ShapeMismatchError(ValueError):
    """The request's ``(latent_shape, crf_shape)`` (or its
    ``init_latents``) does not match the deployment's declared shape
    ladder.  Raised at the API boundary (``Scheduler.submit`` /
    ``FleetRouter.submit``) instead of failing deep inside the jitted
    executable — or worse, silently minting a new compiled signature."""


# canonical shape key: ((H, W, C) latent shape, (S, D) per-sample CRF
# shape) — the shape half of a (batch-bucket, shape-bucket) signature
ShapeKey = Tuple[Tuple[int, ...], Tuple[int, ...]]


def resolve_shape_key(latent_shape, crf_shape,
                      default_shape: Optional[ShapeKey],
                      allowed_shapes=None) -> Optional[ShapeKey]:
    """Canonicalize a request's (possibly partial) shape declaration.

    Both fields ``None`` -> the deployment default.  One field given ->
    completed from the unique ladder entry matching it (so a client may
    declare just the latent size), falling back to the default's other
    half.  Returns ``None`` only when no default is known (a bare
    scheduler outside any engine).
    """
    if latent_shape is None and crf_shape is None:
        return default_shape
    lat = tuple(latent_shape) if latent_shape is not None else None
    crf = tuple(crf_shape) if crf_shape is not None else None
    if (lat is None or crf is None) and allowed_shapes:
        matches = [s for s in allowed_shapes
                   if (lat is None or s[0] == lat)
                   and (crf is None or s[1] == crf)]
        if len(matches) == 1:
            return matches[0]
    if lat is None or crf is None:
        d = default_shape if default_shape is not None else (None, None)
        lat = lat if lat is not None else d[0]
        crf = crf if crf is not None else d[1]
    return (lat, crf)


def validate_request_shape(req, default_shape: Optional[ShapeKey],
                           allowed_shapes=None) -> Optional[ShapeKey]:
    """Resolve ``req``'s shape key and fail fast on a mismatch.

    Raises :class:`ShapeMismatchError` when the resolved key is outside
    the declared ladder, or when ``init_latents`` disagrees with the
    resolved latent shape (previously an opaque trace/broadcast error
    deep inside the donated-buffer executable).  Returns the resolved
    key (``None`` when nothing is declared — no validation possible).
    """
    shape = resolve_shape_key(req.latent_shape, req.crf_shape,
                              default_shape, allowed_shapes)
    if shape is None or shape[0] is None or shape[1] is None:
        return shape
    if allowed_shapes is not None and shape not in allowed_shapes:
        ladder = sorted(allowed_shapes)
        raise ShapeMismatchError(
            f"request {req.request_id}: shape {shape} is not in the "
            f"declared shape ladder {ladder}; declare it at engine "
            "construction (shapes=[...]) or warmup(shapes=[...])")
    if req.init_latents is not None:
        ref_shape = getattr(req.init_latents, "shape", None)
        if ref_shape is not None and tuple(ref_shape) != shape[0]:
            raise ShapeMismatchError(
                f"request {req.request_id}: init_latents shape "
                f"{tuple(ref_shape)} != declared latent shape {shape[0]}")
    return shape


@dataclasses.dataclass
class DiffusionRequest:
    """The single submission type for every serving path.

    Sync (``DiffusionEngine.submit`` / ``run_batch(reqs=...)``) and
    async (``AsyncDiffusionEngine.submit``) consume this object with
    identical field semantics; open-loop drivers carry the planned
    arrival offset in ``arrival_s`` instead of side-channel tuples.
    """
    request_id: int
    seed: int
    # optional conditioning (e.g. reference latents for editing)
    init_latents: Optional[object] = None
    edit_strength: float = 0.0
    # per-request cache policy (CachePolicy spec or Policy object);
    # None -> the engine's default.  Requests with different policies
    # share a batch lane-by-lane (per-lane activation masks).
    policy: Optional[object] = None
    # serving QoS: cut a batch early rather than let this lapse
    deadline_s: Optional[float] = None
    # quality SLO: max prediction error the cache may accumulate
    # between full forwards (snapped down to a budget tier by
    # ``Policy.with_budget``).  None -> the policy's own default
    # behaviour, bit-identical to serving without the SLO field.
    max_error: Optional[float] = None
    # multi-resolution serving: this request's latent [H, W, C] and
    # per-sample CRF [S, D] shapes.  None -> the engine's defaults.
    # Validated against the declared shape ladder at submit time
    # (ShapeMismatchError on mismatch); batches are always cut
    # shape-pure, so the (batch-bucket, shape) signature is warmed.
    latent_shape: Optional[Tuple[int, ...]] = None
    crf_shape: Optional[Tuple[int, ...]] = None
    # open-loop stream plans: seconds after stream start at which this
    # request should be submitted (0.0 for closed-loop clients)
    arrival_s: float = 0.0
    # accounting (stamped by Scheduler.submit)
    submit_time: float = 0.0
    # the budget actually served: == max_error normally, relaxed to a
    # looser tier by load shedding when the queue is deep (stamped by
    # Scheduler.submit; requests are never dropped)
    effective_max_error: Optional[float] = None


class BatchPlan(NamedTuple):
    requests: List[DiffusionRequest]
    bucket: int          # padded batch signature the engine will run
    formed_at: float     # scheduler clock when the batch was cut
    group_key: object = None   # compatibility group this cut came from
    # budget-effective per-real-lane policies (stamped by form_batch:
    # the request policy specialized to its effective_max_error tier);
    # None entries fall back to the engine default in lane_policies
    policies: Optional[List[object]] = None
    # shape half of the (batch-bucket, shape-bucket) signature: every
    # cut is shape-pure, so one pair covers the whole batch.  None ->
    # the engine's default shapes (single-shape deployments).
    latent_shape: Optional[Tuple[int, ...]] = None
    crf_shape: Optional[Tuple[int, ...]] = None

    @property
    def signature(self) -> tuple:
        """(batch-bucket, shape-bucket) — the compiled-executable key
        this plan will run under (shape ``None`` = engine default)."""
        shape = (None if self.latent_shape is None and self.crf_shape is
                 None else (self.latent_shape, self.crf_shape))
        return (self.bucket, shape)

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def occupancy(self) -> float:
        return self.n_real / max(self.bucket, 1)

    def lane_policies(self, default) -> List[object]:
        """Per-lane policy assignment; padded lanes reuse the first real
        lane's policy, so a uniform batch keeps one signature per bucket
        (the warmed ladder) and scheduled pads activate only on steps the
        real lanes already paid for — never forcing extra forwards of
        their own."""
        if self.policies is not None:
            lanes = [p if p is not None else default
                     for p in self.policies]
        else:
            lanes = [r.policy if r.policy is not None else default
                     for r in self.requests]
        pad = lanes[0] if lanes else default
        lanes += [pad] * (self.bucket - self.n_real)
        return lanes


def bucket_sizes(max_batch: int) -> List[int]:
    """Powers of two up to ``max_batch`` (always including max_batch)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return sizes


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest ladder signature that fits ``n`` requests.

    The ladder is ``bucket_sizes(max_batch)``: every power of two below
    ``max_batch`` plus ``max_batch`` itself.  With a non-power-of-two
    ``max_batch`` a cut sized between the largest power of two and
    ``max_batch`` therefore pads straight to ``max_batch`` (e.g. n=5,
    max_batch=6 -> 6; n=5, max_batch=7 -> 7) — intermediate sizes are
    deliberately NOT signatures, so the executable count stays
    O(log max_batch).  The ladder always ends at ``max_batch >= n``
    (checked above), so the scan below always yields.
    """
    if n < 1:
        raise ValueError(f"need at least one request, got {n}")
    if n > max_batch:
        raise ValueError(f"{n} requests exceed max_batch={max_batch}")
    return next(b for b in bucket_sizes(max_batch) if b >= n)


def bucket_signature(n: int, max_batch: int,
                     shape: Optional[ShapeKey] = None) -> tuple:
    """The (batch-bucket, shape-bucket) signature for ``n`` requests of
    one shape — the key the engine's compiled-executable cache is
    bounded by (``shapes x groups x buckets``).  ``shape=None`` is the
    single-shape deployment (engine default)."""
    return (bucket_for(n, max_batch), shape)


class Scheduler:
    """FIFO request queue with age/deadline-triggered batch cutting.

    Thread-safe: all queue access happens under ``cv`` (a reentrant
    condition variable), and every ``submit`` notifies waiters.

    ``group_policies=True`` turns on policy-homogeneous batch formation
    (see the module docstring); ``default_policy`` is what a request
    with ``policy=None`` resolves to for grouping.
    """

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.05,
                 pad_to_max: bool = False, clock=time.monotonic,
                 group_policies: bool = False, default_policy=None,
                 shed_depth: Optional[int] = None,
                 shed_factor: float = 4.0,
                 default_shape: Optional[ShapeKey] = None,
                 allowed_shapes: Optional[set] = None):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.pad_to_max = pad_to_max  # seed-compatible fixed signature
        self.clock = clock
        self.group_policies = group_policies
        self.default_policy = default_policy
        # multi-resolution serving: the engine's default
        # (latent_shape, crf_shape) pair and the declared shape ladder
        # submits are validated against.  ``allowed_shapes`` is held by
        # reference (the engine shares its own set), so shapes declared
        # after construction — warmup(shapes=[...]) — are honoured.
        # None/None = a bare scheduler: shape validation is skipped and
        # every request files under one pseudo-shape.
        self.default_shape = default_shape
        self.allowed_shapes = (allowed_shapes if allowed_shapes is not None
                               else ({default_shape} if default_shape
                                     is not None else None))
        # load shedding: when the queue holds >= shed_depth requests at
        # submit time, the incoming request's effective error budget is
        # relaxed by shed_factor (snapped to a looser tier) — quality is
        # shed, never the request itself
        self.shed_depth = shed_depth
        self.shed_factor = shed_factor
        self.shed_events = 0
        self.queue: List[DiffusionRequest] = []
        self.submitted = 0
        # sanitizer-aware: a plain Condition(RLock()) unless
        # REPRO_SANITIZE=1, then lock-order-instrumented
        self.cv = make_condition("Scheduler.cv")
        self._key_cache: dict = {}   # policy/spec -> compatibility key
        self._pol_cache: dict = {}   # (policy, budget) -> effective Policy

    def __len__(self) -> int:
        with self.cv:
            return len(self.queue)

    @property
    def depth(self) -> int:
        return len(self)

    def validate(self, req: DiffusionRequest) -> Optional[ShapeKey]:
        """Resolve + validate the request's shape against the declared
        ladder (see :func:`validate_request_shape`); raises
        :class:`ShapeMismatchError` without touching the queue."""
        return validate_request_shape(req, self.default_shape,
                                      self.allowed_shapes)

    def shape_of(self, req: DiffusionRequest) -> Optional[ShapeKey]:
        """Canonical shape key this request files under (no validation
        — submit already did that)."""
        return resolve_shape_key(req.latent_shape, req.crf_shape,
                                 self.default_shape, self.allowed_shapes)

    def submit(self, req: DiffusionRequest,
               now: Optional[float] = None) -> None:
        with self.cv:
            # fail fast BEFORE any queue/counter mutation: a rejected
            # request leaves no trace (submitted stays in step with the
            # serve path)
            self.validate(req)
            req.submit_time = self.clock() if now is None else now
            req.effective_max_error = req.max_error
            if (req.max_error is not None and self.shed_depth is not None
                    and len(self.queue) >= self.shed_depth):
                req.effective_max_error = req.max_error * self.shed_factor
                self.shed_events += 1
            self.queue.append(req)
            self.submitted += 1
            self.cv.notify_all()

    def _lapsed(self, now: float) -> List[int]:
        """Queue indices whose deadline has already passed."""
        return [i for i, r in enumerate(self.queue)
                if r.deadline_s is not None
                and now - r.submit_time >= r.deadline_s]

    def _deadline_pressure(self, now: float) -> bool:
        return bool(self._lapsed(now))

    def effective_policy(self, req: DiffusionRequest):
        """The policy this request will actually be served with: its own
        (or the default), specialized to the effective error budget —
        ``Policy.with_budget`` snaps the budget to a tier, so the number
        of distinct effective policies stays bounded."""
        pol = req.policy if req.policy is not None else self.default_policy
        budget = req.effective_max_error
        if pol is None or budget is None:
            return pol
        ck = (pol, budget)
        got = self._pol_cache.get(ck)
        if got is None:
            from repro.core.policies import registry
            got = self._pol_cache[ck] = (
                registry.resolve(pol).with_budget(budget))
        return got

    def group_key(self, req: DiffusionRequest):
        """Compatibility-group key of a request's (resolved) policy,
        budget tier included — ``with_budget`` returns a distinct policy
        value per tier and adaptive policies key on their full value, so
        requests group by (policy, budget tier) automatically."""
        pol = self.effective_policy(req)
        if pol is None:
            return None
        key = self._key_cache.get(pol)
        if key is None:
            from repro.core.policies import registry
            key = self._key_cache[pol] = registry.compatibility_key(pol)
        return key

    def _cut_key(self, req: DiffusionRequest) -> tuple:
        """(shape key, compatibility key) a cut must be pure in.

        The shape half ALWAYS folds in — mixed-shape lanes cannot share
        one executable (``jnp.stack`` would fail outright), so shape
        purity is a physical requirement of every former, grouped or
        not.  The policy half folds in only under ``group_policies``
        (the PR-5 ``compatibility_key()`` path).  A single-shape
        ungrouped deployment collapses to one constant key — the
        original whole-queue FIFO former, bit-identical.
        """
        return (self.shape_of(req),
                self.group_key(req) if self.group_policies else None)

    def groups(self) -> dict:
        """Queued request count per (shape, compatibility-group) cut key
        (one pseudo-group of the whole queue for a bare single-shape
        ungrouped scheduler)."""
        with self.cv:
            counts: dict = {}
            for r in self.queue:
                k = self._cut_key(r)
                counts[k] = counts.get(k, 0) + 1
            return counts

    def _full_group(self) -> bool:
        """Can some (shape- and group-pure) cut fill the largest bucket
        right now?"""
        return any(n >= self.max_batch for n in self.groups().values())

    def ready(self, now: Optional[float] = None) -> bool:
        """Would ``form_batch`` cut a batch right now (without flushing)?

        Under ``group_policies`` the full-queue trigger becomes a
        full-*group* trigger: ten requests spread over three groups fill
        no policy-pure bucket, so only age/deadline pressure cuts.
        """
        with self.cv:
            if not self.queue:
                return False
            now = self.clock() if now is None else now
            if self._full_group():
                return True
            oldest_age = now - self.queue[0].submit_time
            return (oldest_age >= self.max_wait_s
                    or self._deadline_pressure(now))

    def seconds_until_ready(self, now: Optional[float] = None
                            ) -> Optional[float]:
        """How long until age/deadline pressure would cut a batch.

        Returns ``None`` for an empty queue (nothing to wait for — a
        submit will notify ``cv``), ``0.0`` if a batch is ready now, else
        the soonest of (oldest request hitting ``max_wait_s``, earliest
        deadline lapsing).  A worker can ``cv.wait(...)`` exactly this
        long instead of sleep-polling.
        """
        with self.cv:
            if not self.queue:
                return None
            now = self.clock() if now is None else now
            if self.ready(now):
                return 0.0
            until = self.max_wait_s - (now - self.queue[0].submit_time)
            for r in self.queue:
                if r.deadline_s is not None:
                    until = min(until,
                                r.deadline_s - (now - r.submit_time))
            return max(until, 0.0)

    def _cut_group(self, now: float, flush: bool):
        """(key, member queue-indices in FIFO order) of the next cut.

        Keys are ``_cut_key`` values — (shape, compatibility group) —
        so every cut is shape-pure in any mode and policy-pure under
        grouping."""
        keys = [self._cut_key(r) for r in self.queue]
        lapsed = self._lapsed(now)
        if lapsed:
            # a lapsed deadline wins: the most-overdue request's group
            # is the next cut (its lapsed members get promoted below)
            j = max(lapsed, key=lambda i: now - self.queue[i].submit_time
                    - self.queue[i].deadline_s)
            key = keys[j]
        elif flush or now - self.queue[0].submit_time >= self.max_wait_s:
            # age pressure / drain: FIFO across groups — the oldest
            # request's group, so a rare policy is served as soon as its
            # request heads the queue and can never be starved by a
            # busier group
            key = keys[0]
        else:
            # full-bucket trigger alone: the full group with the
            # earliest-submitted member
            counts: dict = {}
            for k in keys:
                counts[k] = counts.get(k, 0) + 1
            key = next(k for k in keys if counts[k] >= self.max_batch)
        return key, [i for i, k in enumerate(keys) if k == key]

    def form_batch(self, now: Optional[float] = None,
                   flush: bool = False) -> Optional[BatchPlan]:
        """Cut the next batch, or None if nothing is ready yet.

        Deadline-lapsed requests are promoted into the cut wherever they
        sit in the queue (a lapsed request beyond position ``max_batch``
        used to trigger the cut yet be excluded from it — and could lapse
        indefinitely under sustained load); the remaining slots are the
        FIFO prefix, and the batch keeps stable FIFO order overall.

        Under ``group_policies`` the same rule is applied to the members
        of one compatibility group (chosen by ``_cut_group``), so every
        emitted batch is policy-pure and lapsed requests of *other*
        groups are served by the immediately following cuts — deadline
        priority picks their group next.
        """
        with self.cv:
            now = self.clock() if now is None else now
            if not self.queue or not (flush or self.ready(now)):
                return None
            # every cut goes through the group machinery: the key is
            # (shape, policy-group-or-None), so cuts are shape-pure in
            # ANY mode (mixed shapes can't share an executable) and a
            # single-shape ungrouped queue degenerates to one constant
            # key — the whole-queue FIFO former, unchanged
            (shape, gkey), members = self._cut_group(now, flush)
            lapsed_set = set(self._lapsed(now))
            take = min(len(members), self.max_batch)
            picked = [i for i in members if i in lapsed_set][:take]
            picked_set = set(picked)
            for i in members:
                if len(picked) >= take:
                    break
                if i not in picked_set:
                    picked.append(i)
                    picked_set.add(i)
            reqs = [self.queue[i] for i in sorted(picked)]  # stable FIFO
            if self.group_policies:
                reqs = self._canonical_lane_order(reqs)
            self.queue = [r for i, r in enumerate(self.queue)
                          if i not in picked_set]
            bucket = (self.max_batch if self.pad_to_max
                      else bucket_for(take, self.max_batch))
            return BatchPlan(requests=reqs, bucket=bucket, formed_at=now,
                             group_key=gkey,
                             policies=[self.effective_policy(r)
                                       for r in reqs],
                             latent_shape=(shape[0] if shape else None),
                             crf_shape=(shape[1] if shape else None))

    def _canonical_lane_order(self, reqs: List[DiffusionRequest]
                              ) -> List[DiffusionRequest]:
        """Canonical lane order for a family cut mixing distinct member
        policies (e.g. ``fora(interval=1)`` + ``none``).

        Lane order inside one cut is semantically free — lanes run
        simultaneously and results map back per request — so the lanes
        are stable-sorted by policy value: the engine's jit signature
        then depends on the batch's policy *composition* only, never on
        arrival interleaving (one executable per composition instead of
        one per ordering).  Value-pure cuts (the common case) pass
        through untouched, and FIFO order is preserved within each
        policy value.
        """
        pols = [self.effective_policy(r) for r in reqs]
        if all(p == pols[0] for p in pols):
            return reqs
        order = sorted(range(len(reqs)), key=lambda i: repr(pols[i]))
        return [reqs[i] for i in order]
