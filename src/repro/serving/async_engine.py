"""True async serving: a thread-safe submit path over ``DiffusionEngine``.

``AsyncDiffusionEngine`` wraps a (warmed) ``DiffusionEngine``:

* ``submit(request)`` is safe from any number of client threads and
  returns a ``concurrent.futures.Future`` immediately — it resolves to
  the request's ``DiffusionResult`` when its batch completes (or raises
  the batch's exception / ``CancelledError`` on a no-drain shutdown).
  It takes the same ``DiffusionRequest`` object as the sync
  ``DiffusionEngine.submit`` / ``run_batch(reqs=...)`` path — one
  request type across both APIs — so per-request quality SLOs
  (``max_error``) and load-shedding behave identically: budget
  stamping and shedding happen inside ``Scheduler.submit``, which both
  routes share.
* one background worker thread owns the whole batch-formation →
  ``execute_plan`` loop.  It blocks on the scheduler's condition
  variable and wakes on submits or exactly when age/deadline pressure
  would cut a batch (``Scheduler.seconds_until_ready``) — no
  sleep-polling, and deadline-lapsed requests are promoted into the
  next cut by the scheduler.  Under a policy-grouping scheduler the
  worker executes one plan per compatibility group back to back (each
  cut is policy-pure; a drain flushes the remaining groups one cut at
  a time), so clients of different policies never share — or pay for —
  each other's activations.
* results stream back as batches complete: each future is resolved by
  the worker the moment its batch's device work finishes, so clients
  overlap the engine instead of replaying a plan serially.

Lock discipline: the scheduler's ``cv`` guards the queue *and* this
engine's future map / lifecycle flags; jit dispatch, device transfers,
and metrics recording happen outside the lock (metrics carry their own
lock).  ``drain()`` waits for everything submitted so far; ``shutdown``
(also via context manager) stops the worker, by default draining first
— no request is ever lost or double-served (futures resolve exactly
once, enforced by ``Future`` itself and stress-tested).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import CancelledError  # noqa: F401  (re-export)
from concurrent.futures import Future, InvalidStateError, wait
from typing import List, Optional, Sequence

from repro.serving.engine import DiffusionEngine
from repro.serving.scheduler import DiffusionRequest

__all__ = ["AsyncDiffusionEngine", "CancelledError"]


class AsyncDiffusionEngine:
    """Threaded submit path + single worker around a ``DiffusionEngine``.

    Construct over an existing engine (warm it first so the serving
    phase is compile-free), then either use as a context manager or call
    ``start()`` / ``shutdown()`` explicitly::

        eng = DiffusionEngine(...)
        eng.warmup()
        with AsyncDiffusionEngine(eng) as aeng:
            futs = [aeng.submit(req) for req in reqs]   # any thread(s)
            outs = [f.result() for f in futs]
    """

    def __init__(self, engine: DiffusionEngine):
        self.engine = engine
        self.scheduler = engine.scheduler
        self.metrics = engine.metrics
        self._futures = {}            # id(request) -> Future (queued)
        self._inflight = {}           # id(request) -> Future (running batch)
        self._stop = False
        self._drains = 0              # drains in progress (flush mode)
        self._worker: Optional[threading.Thread] = None
        self._t0: Optional[float] = None

    # --- lifecycle -------------------------------------------------------
    def start(self) -> "AsyncDiffusionEngine":
        with self.scheduler.cv:
            if self._stop:
                raise RuntimeError("engine has been shut down")
            if self._worker is None:
                self._t0 = time.perf_counter()
                self._worker = threading.Thread(
                    target=self._run, name="diffusion-engine-worker",
                    daemon=True)
                self._worker.start()
        return self

    def __enter__(self) -> "AsyncDiffusionEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    def warmup(self, buckets: Optional[Sequence[int]] = None,
               lane_policy_sets: Sequence[Sequence[object]] = (),
               policies: Sequence[object] = (),
               shapes: Sequence = ()) -> float:
        return self.engine.warmup(buckets, lane_policy_sets,
                                  policies=policies, shapes=shapes)

    def metrics_dict(self):
        """Fleet-export hook: lossless snapshot of the shared metrics."""
        return self.engine.metrics_dict()

    # --- submit path -----------------------------------------------------
    def submit(self, req: DiffusionRequest,
               now: Optional[float] = None) -> Future:
        """Enqueue a request; returns its future immediately.

        Thread-safe.  The future resolves to a ``DiffusionResult`` when
        the request's batch completes.
        """
        fut: Future = Future()
        with self.scheduler.cv:
            if self._stop:
                raise RuntimeError("engine has been shut down")
            if id(req) in self._futures or id(req) in self._inflight:
                raise ValueError(
                    "request object is already pending; submit a fresh "
                    "DiffusionRequest per attempt")
            if self._worker is None:
                self.start()
            # submit BEFORE registering the future: scheduler.submit
            # validates shapes and may raise (ShapeMismatchError) — the
            # future map must not keep an entry for a rejected request.
            # Safe under the reentrant cv: the worker can't observe the
            # queued-but-unregistered state until we release the lock.
            self.scheduler.submit(req, now=now)   # notifies the worker
            self._futures[id(req)] = fut
        return fut

    def pending(self) -> int:
        """Requests submitted but not yet resolved (queued + in flight)."""
        with self.scheduler.cv:
            return len(self._futures) + len(self._inflight)

    # --- drain / shutdown ------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until everything submitted so far has resolved.

        Wakes the worker in flush mode so a waiting partial batch is cut
        immediately instead of aging out.  Returns False on timeout.
        """
        with self.scheduler.cv:
            outstanding = (list(self._futures.values())
                           + list(self._inflight.values()))
            self._drains += 1         # refcount: concurrent drains stack
            self.scheduler.cv.notify_all()
        try:
            done, not_done = wait(outstanding, timeout=timeout)
        finally:
            with self.scheduler.cv:
                self._drains -= 1
        return not not_done

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the worker.  ``drain=True`` serves everything already
        queued first; ``drain=False`` cancels queued requests (their
        futures raise ``CancelledError``).  Idempotent."""
        with self.scheduler.cv:
            self._stop = True
            if not drain:
                for r in list(self.scheduler.queue):
                    fut = self._futures.pop(id(r), None)
                    if fut is not None:
                        fut.cancel()
                self.scheduler.queue.clear()
            self.scheduler.cv.notify_all()
            worker = self._worker
        if worker is not None:
            worker.join(timeout)
            if worker.is_alive():
                raise TimeoutError("engine worker did not stop in "
                                   f"{timeout}s")

    # --- worker ----------------------------------------------------------
    def _run(self) -> None:
        sched = self.scheduler
        while True:
            with sched.cv:
                plan = None
                while plan is None:
                    if not sched.queue:
                        if self._stop:
                            return
                        sched.cv.wait()
                        continue
                    flush = self._stop or self._drains > 0
                    self.metrics.observe_queue_depth(len(sched.queue))
                    plan = sched.form_batch(flush=flush)
                    if plan is None:
                        # deadline-aware nap: wake exactly when age or a
                        # deadline would cut (or earlier, on a submit)
                        sched.cv.wait(sched.seconds_until_ready())
                # a future whose client already cancelled it is dropped
                # here (its lane still runs — the plan is cut); the rest
                # move to RUNNING so late cancels can no longer race the
                # worker's set_result
                futs = []
                for r in plan.requests:
                    fut = self._futures.pop(id(r), None)
                    if fut is not None and \
                            not fut.set_running_or_notify_cancel():
                        fut = None
                    futs.append(fut)
                    if fut is not None:
                        self._inflight[id(r)] = fut
            try:
                self._serve(plan, futs)
            finally:
                with sched.cv:
                    self._inflight.clear()

    def _serve(self, plan, futs: List[Optional[Future]]) -> None:
        try:
            results = self.engine.execute_plan(plan)
        except BaseException as e:   # resolve, don't kill the worker
            for fut in futs:
                if fut is not None and not fut.done():
                    fut.set_exception(e)
            return
        if self._t0 is not None:
            self.metrics.observe_first_result(time.perf_counter() - self._t0)
        for fut, res in zip(futs, results, strict=True):
            if fut is None:
                continue
            try:
                fut.set_result(res)
            except InvalidStateError:
                # the future moved to RUNNING above, so a client cancel
                # can't race us — but a second resolution must degrade
                # to a counter, never kill the worker thread
                self.metrics.observe_duplicate_result()
