"""Replica supervision: restart dead workers, retire crash-loopers.

``FleetSupervisor`` watches a ``FleetRouter``'s replica slots from its
own thread.  When a slot goes dead (crash, SIGKILL, stale-pong kill —
anything that tripped the router's death path) it schedules a restart
with exponential backoff (``backoff_base_s * 2**attempts``, capped at
``backoff_cap_s``), spawns a fresh ``Replica`` from the router's
stored factory/warm/env via ``FleetRouter._spawn_replica`` (so the
fault injector sees the new incarnation number), waits for it to boot
+ warm, and adopts it back into the slot — at which point the router
routes to it again and re-places any parked work.

Attempts are counted per slot over the fleet's lifetime: once a slot
has consumed ``max_restarts`` attempts (successful or not) and dies
again, it is **retired** — permanently removed from supervision — so a
crash-looping replica cannot burn the fleet forever.  Counters:
``restarts`` (successful adoptions), ``boot_failures`` (restart
attempts whose worker never became ready), ``replicas_retired``, and
``restart_backoff_s`` (cumulative scheduled backoff).

Lock discipline: the supervisor takes the router's lock only for
short state snapshots / adoption, and never holds its own state while
doing so — there is no router-lock → supervisor-lock edge, so the
runtime lock-order sanitizer stays quiet.  ``can_recover`` is
deliberately lock-free (reads a set maintained by the supervisor
thread) because the router calls it while holding its own lock.
"""
from __future__ import annotations

import threading
import time
from typing import Dict

__all__ = ["FleetSupervisor"]


class FleetSupervisor:
    """Restart dead replica slots with capped exponential backoff.

    Created (and started) by ``FleetRouter.start`` when the router is
    constructed with ``max_restarts > 0``; usable standalone against
    any started router.
    """

    def __init__(self, router, max_restarts: int = 2,
                 backoff_base_s: float = 0.5, backoff_cap_s: float = 30.0,
                 poll_interval_s: float = 0.1):
        if max_restarts < 1:
            raise ValueError(
                f"max_restarts must be >= 1, got {max_restarts}")
        self.router = router
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.poll_interval_s = poll_interval_s
        # slot idx -> {"attempts": int, "next_try": float | None}
        # (touched only by the supervisor thread)
        self._slots: Dict[int, dict] = {}
        self.retired_slots: set = set()
        self.counters: Dict[str, int] = {
            "restarts": 0, "boot_failures": 0, "replicas_retired": 0,
        }
        self.restart_backoff_s = 0.0
        self._stop = threading.Event()
        self._thread = None

    # --- lifecycle -------------------------------------------------------
    def start(self) -> "FleetSupervisor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="fleet-supervisor", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)

    # --- policy ----------------------------------------------------------
    def backoff_s(self, attempts: int) -> float:
        """Backoff before attempt ``attempts`` (0-based): base·2^k, capped."""
        return min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** attempts))

    def can_recover(self) -> bool:
        """True while some slot could still come (back) up — the router
        parks orphans instead of failing them when this holds.  Lock-free
        on purpose: called under the router's lock."""
        return len(self.retired_slots) < self.router.n_replicas

    def state(self) -> Dict:
        """Counters + per-slot attempt/retire view (for status/benches)."""
        return {
            **self.counters,
            "restart_backoff_s": round(self.restart_backoff_s, 3),
            "retired_slots": sorted(self.retired_slots),
            "slots": {idx: {"attempts": s["attempts"],
                            "retired": idx in self.retired_slots}
                      for idx, s in self._slots.items()},
        }

    # --- supervision loop ------------------------------------------------
    def _loop(self) -> None:
        while not self._stop.wait(self.poll_interval_s):
            try:
                self._tick()
            except Exception:
                # supervision must outlive any single bad tick
                continue

    def _dead_slots(self):
        with self.router._lock:
            if self.router._stopping:
                return None
            return [r.idx for r in self.router.replicas
                    if not r.healthy and not r.stopped]

    def _tick(self) -> None:
        dead = self._dead_slots()
        if dead is None:        # router shutting down
            return
        now = time.monotonic()
        for idx in dead:
            if idx in self.retired_slots:
                continue
            slot = self._slots.setdefault(
                idx, {"attempts": 0, "next_try": None})
            if slot["attempts"] >= self.max_restarts:
                self.retired_slots.add(idx)
                self.counters["replicas_retired"] += 1
                continue
            if slot["next_try"] is None:
                wait = self.backoff_s(slot["attempts"])
                slot["next_try"] = now + wait
                self.restart_backoff_s += wait
                continue
            if now < slot["next_try"]:
                continue
            slot["attempts"] += 1
            slot["next_try"] = None
            if self._restart(idx):
                self.counters["restarts"] += 1
            else:
                self.counters["boot_failures"] += 1
                wait = self.backoff_s(slot["attempts"])
                slot["next_try"] = time.monotonic() + wait
                self.restart_backoff_s += wait

    def _restart(self, idx: int) -> bool:
        """One restart attempt for slot ``idx``; True once the new
        worker is ready and adopted by the router."""
        router = self.router
        old = router.replicas[idx]
        old.destroy()           # reap the corpse, close its pipe fds
        try:
            r = router._spawn_replica(idx)
        except Exception:
            return False
        # wait_ready in slices so stop() interrupts a long warmup wait
        deadline = time.monotonic() + router.boot_timeout_s
        while True:
            if self._stop.is_set() or router._stopping:
                r.destroy()
                return False
            try:
                r.wait_ready(min(0.25, max(deadline - time.monotonic(),
                                           0.01)))
                break
            except TimeoutError:
                if time.monotonic() >= deadline:
                    r.destroy()
                    return False
            except Exception:   # boot_error / protocol violation
                r.destroy()
                return False
        router._adopt(idx, r)
        return True
