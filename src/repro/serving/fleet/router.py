"""Policy-aware request router over N engine replicas.

``FleetRouter`` is the fleet analogue of ``AsyncDiffusionEngine``:
``submit(request)`` is thread-safe and returns a
``concurrent.futures.Future`` immediately; ``drain()`` waits for
everything submitted so far (flushing partial batches on every
replica); ``shutdown(drain=True)`` stops the workers gracefully
(``drain=False`` cancels outstanding futures and terminates).  The
difference is *where* batches form: the router never cuts batches
itself — each replica runs its own ``Scheduler`` — so the router's job
is to place requests such that the per-replica schedulers still see
policy-pure streams.

**Routing rule** (compatibility-key affinity + load):  each request is
keyed by its resolved policy's ``compatibility_key()`` with the
``max_error`` budget tier folded in (``Policy.with_budget`` — the same
key the replica's scheduler groups by).  A group has a *home* replica;
requests follow their home while it stays healthy and within
``spill_slack`` outstanding requests of the least-loaded replica, so a
group's requests pile onto ONE queue and fill policy-pure buckets
fleet-wide instead of fragmenting into per-replica singles.  When the
home falls behind by more than ``spill_slack`` (default: the replica's
``max_batch`` — one full bucket of slack), the group *spills*: the
least-loaded replica becomes the new home.  New groups start on the
least-loaded replica; a group whose home died also counts as a spill.
Decisions are counted
(``affinity_hits`` / ``new_groups`` / ``spills`` / ``requeued``) and
reported through ``FleetMetrics``.

**Health / failure**:  a monitor thread pings every replica on
``health_interval_s``; one receiver thread per replica streams results
back and resolves futures.  A dead replica is detected by pipe EOF
(crash/SIGKILL) or a stale pong (hung worker — it is then killed
exactly once, counted in ``stale_pong_kills``, so the EOF path takes
over).  Death handling runs on the receiver thread *after* the pipe
buffer is fully drained, so results that raced the crash still
resolve; everything left in the replica's in-flight map is requeued
onto the surviving replicas (sampling is deterministic per request
seed, so a re-run resolves to the same latents) and each future still
resolves exactly once.

**Self-healing** (``max_restarts > 0``):  a ``FleetSupervisor`` thread
restarts dead slots with capped exponential backoff and permanently
retires crash-loopers; while recovery is possible, orphans that find
no healthy survivor are *parked* and re-placed the moment a replica
rejoins, instead of failing.  Only when no slot can ever come back do
orphaned futures fail with ``RuntimeError``.

**Retry budget / poison quarantine**:  each in-flight entry carries a
death count.  A request implicated in ``retry_budget`` replica deaths
is quarantined — its future fails with ``PoisonRequestError`` — but
only when the evidence is unambiguous: it was *alone* on the replica
it killed.  A request that reaches its budget in a cohort (other
requests died with it — any of them could be the poison) is parked for
an **isolation probe**: it re-runs solo on an idle replica flagged
``probation`` (excluded from routing), so a genuinely healthy
bystander completes its probe and resolves normally, while a true
poison kills the probation replica solo and is then quarantined.
Healthy traffic can therefore never be failed by someone else's
poison.

**Backpressure** (``max_inflight > 0``):  ``submit()`` blocks while
every healthy replica has ``max_inflight`` requests outstanding, so
router-side queues are bounded by ``replicas × max_inflight`` instead
of growing without limit.  With ``shed_factor`` set, a blocked submit
first relaxes the request's error budget once (``max_error ×
shed_factor`` — the PR-6 quality-shed move: cheaper to serve slightly
coarser than to queue unboundedly) and then waits for a slot.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional

from repro.analysis.runtime import make_condition, make_lock
from repro.serving.fleet.fleet_metrics import FleetMetrics
from repro.serving.fleet.worker import Replica
from repro.serving.scheduler import (DiffusionRequest, ShapeMismatchError,
                                     resolve_shape_key,
                                     validate_request_shape)

__all__ = ["FleetRouter", "PoisonRequestError", "ShapeMismatchError"]


class PoisonRequestError(RuntimeError):
    """The request was implicated — solo — in ``retry_budget`` replica
    deaths and has been quarantined instead of requeued again."""


def _wire_request(req: DiffusionRequest) -> DiffusionRequest:
    """Copy with device arrays made host-side so the request pickles."""
    if req.init_latents is None:
        return req
    import numpy as np
    return dataclasses.replace(req, init_latents=np.asarray(req.init_latents))


def _entry_deaths(entry) -> int:
    """Death count of an in-flight entry; tolerates legacy 2-tuples
    (tests that hand-build fake replicas with ``(req, fut)``)."""
    return entry[2] if len(entry) > 2 else 0


class FleetRouter:
    """Frontend over N replica processes (see module docstring).

    ``factory`` must be a picklable zero-arg callable returning an
    (unwarmed) ``DiffusionEngine`` — a module-level function or a
    ``functools.partial`` of one; each worker calls it in its own
    process.  ``warm`` maps onto ``DiffusionEngine.warmup`` kwargs and
    runs once per replica at boot.  ``default_policy`` mirrors the
    engines' default and is only used to compute affinity keys for
    requests with ``policy=None``.

    Robustness knobs (all off by default, matching the PR-7 fleet):
    ``max_restarts`` enables the supervisor; ``max_inflight`` bounds
    per-replica queues (0 = unbounded); ``retry_budget`` is the number
    of replica deaths a single request may be implicated in before
    quarantine; ``shed_factor`` (> 1) relaxes a blocked request's error
    budget once instead of queueing it forever; ``fault_injector`` is
    the chaos hook (tests/benches only).
    """

    def __init__(self, factory, n_replicas: int = 2, warm: Optional[dict]
                 = None, default_policy=None, worker_env: Optional[dict]
                 = None, spill_slack: Optional[int] = None,
                 health_interval_s: float = 0.25,
                 stale_after_s: float = 30.0,
                 boot_timeout_s: float = 600.0,
                 max_inflight: int = 0,
                 max_restarts: int = 0,
                 retry_budget: int = 2,
                 shed_factor: Optional[float] = None,
                 restart_backoff_base_s: float = 0.5,
                 restart_backoff_cap_s: float = 30.0,
                 fault_injector=None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if retry_budget < 1:
            raise ValueError(f"retry_budget must be >= 1, got {retry_budget}")
        if max_inflight < 0:
            raise ValueError(f"max_inflight must be >= 0, got {max_inflight}")
        self.factory = factory
        self.n_replicas = n_replicas
        self.warm = dict(warm or {})
        self.default_policy = default_policy
        self.worker_env = dict(worker_env or {})
        self.spill_slack = spill_slack
        self.health_interval_s = health_interval_s
        self.stale_after_s = stale_after_s
        self.boot_timeout_s = boot_timeout_s
        self.max_inflight = max_inflight
        self.max_restarts = max_restarts
        self.retry_budget = retry_budget
        self.shed_factor = shed_factor
        self.restart_backoff_base_s = restart_backoff_base_s
        self.restart_backoff_cap_s = restart_backoff_cap_s
        self.fault_injector = fault_injector

        self.replicas: List[Replica] = []
        self.supervisor = None
        self._lock = make_lock("FleetRouter._lock")
        self._cv = make_condition("FleetRouter._cv", lock=self._lock)
        self._home: Dict = {}         # affinity key -> replica idx
        self._key_cache: Dict = {}    # (policy, budget, shapes) -> key
        # shape ladder shared by the replicas (all run the same factory
        # + warm spec): learned from the first replica's ready metadata,
        # used to validate submits at the router boundary and to fold
        # shape into affinity keys.  None with pre-multires workers.
        self._default_shape = None
        self._shape_ladder = None
        self._starts: Dict[int, int] = {}   # slot idx -> spawn count
        self._parked: List[list] = []  # [req, fut, deaths, probe_flag]
        self._next_token = 0
        self._stopping = False
        self._started = False
        self._stop_monitor = threading.Event()
        self._threads: List[threading.Thread] = []
        self.counters: Dict[str, int] = {
            "submitted": 0, "resolved": 0, "failed": 0,
            "affinity_hits": 0, "new_groups": 0, "spills": 0,
            "requeued": 0, "replicas_lost": 0, "duplicate_results": 0,
            "stale_pong_kills": 0, "poison_quarantined": 0,
            "probations": 0, "backpressure_waits": 0,
            "router_shed_events": 0, "peak_inflight": 0,
        }

    # --- lifecycle -------------------------------------------------------
    def _spawn_replica(self, idx: int) -> Replica:
        """Spawn one replica for slot ``idx``; each call is a new
        incarnation (``start_n``) so the fault injector can script
        boot-failure-on-Nth-start."""
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        start_n = self._starts.get(idx, 0)
        self._starts[idx] = start_n + 1
        fault = (self.fault_injector.spec_for(idx, start_n)
                 if self.fault_injector is not None else None)
        return Replica(idx, self.factory, warm=self.warm,
                       env=self.worker_env, ctx=ctx, fault=fault,
                       start_n=start_n)

    def _start_recv(self, r: Replica) -> None:
        th = threading.Thread(target=self._recv_loop, args=(r,),
                              name=f"fleet-recv-{r.idx}", daemon=True)
        th.start()
        self._threads.append(th)

    def start(self) -> "FleetRouter":
        """Spawn all replicas (they boot + warm in parallel), wait until
        every one is ready, then start the receiver/monitor threads
        (and the supervisor, when ``max_restarts > 0``)."""
        with self._lock:
            if self._stopping:
                raise RuntimeError("router has been shut down")
            if self._started:
                return self
            self._started = True
        self.replicas = [self._spawn_replica(i)
                         for i in range(self.n_replicas)]
        deadline = time.monotonic() + self.boot_timeout_s
        try:
            for r in self.replicas:
                r.wait_ready(max(deadline - time.monotonic(), 0.1))
        except BaseException:
            # never leak a stuck child: kill + reap + close every pipe
            for r in self.replicas:
                r.destroy()
            raise
        if self.spill_slack is None:
            self.spill_slack = max(r.meta.get("max_batch", 1)
                                   for r in self.replicas)
        meta0 = self.replicas[0].meta
        if meta0.get("shapes"):
            self._shape_ladder = {
                (tuple(s[0]), tuple(s[1])) for s in meta0["shapes"]}
        if meta0.get("default_shape"):
            ds = meta0["default_shape"]
            self._default_shape = (tuple(ds[0]), tuple(ds[1]))
        for r in self.replicas:
            self._start_recv(r)
        mon = threading.Thread(target=self._monitor, name="fleet-monitor",
                               daemon=True)
        mon.start()
        self._threads.append(mon)
        if self.max_restarts > 0:
            from repro.serving.fleet.supervisor import FleetSupervisor
            self.supervisor = FleetSupervisor(
                self, max_restarts=self.max_restarts,
                backoff_base_s=self.restart_backoff_base_s,
                backoff_cap_s=self.restart_backoff_cap_s).start()
        return self

    def _adopt(self, idx: int, r: Replica) -> None:
        """Swap a freshly-booted replica into slot ``idx`` (supervisor
        restart path) and re-place any parked work on it."""
        with self._cv:
            self.replicas[idx] = r
            self._cv.notify_all()   # blocked submits: capacity is back
        self._start_recv(r)
        self._place_parked()

    def __enter__(self) -> "FleetRouter":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    # --- routing ---------------------------------------------------------
    def _affinity_key(self, req: DiffusionRequest):
        """The cut key the replica's scheduler will file this request
        under: (resolved policy with budget tier folded in, canonical
        shape key) — mirroring ``Scheduler._cut_key``, so a
        (policy, shape) group piles onto ONE replica and fills
        shape-pure buckets fleet-wide."""
        pol = req.policy if req.policy is not None else self.default_policy
        lat = (tuple(req.latent_shape)
               if req.latent_shape is not None else None)
        crf = tuple(req.crf_shape) if req.crf_shape is not None else None
        ck = (pol, req.max_error, lat, crf)
        key = self._key_cache.get(ck)
        if key is None:
            if pol is None:
                pkey = ("default", req.max_error)
            else:
                from repro.core.policies import registry
                pkey = registry.compatibility_key(
                    registry.resolve(pol).with_budget(req.max_error))
            shape = resolve_shape_key(lat, crf, self._default_shape,
                                      self._shape_ladder)
            key = (pkey, shape)
            self._key_cache[ck] = key
        return key

    def _candidates(self, respect_cap: bool) -> List[Replica]:
        """Routable replicas: healthy, not running an isolation probe,
        and (for fresh submits) below ``max_inflight``."""
        return [r for r in self.replicas
                if r.healthy and not getattr(r, "probation", False)
                and (not respect_cap or self.max_inflight <= 0
                     or len(r.inflight) < self.max_inflight)]

    def _route(self, req: DiffusionRequest,
               respect_cap: bool = False) -> Replica:
        """Pick a replica (call with ``self._lock`` held)."""
        healthy = self._candidates(respect_cap)
        if not healthy:
            raise RuntimeError("no healthy replicas")
        key = self._affinity_key(req)
        least = min(healthy, key=lambda r: (len(r.inflight), r.idx))
        idx = self._home.get(key)
        home = next((r for r in healthy if r.idx == idx), None)
        if home is None:
            # brand-new group, or the home died / is at capacity —
            # either way the group moves to the least-loaded replica
            self._home[key] = least.idx
            self.counters["new_groups" if idx is None else "spills"] += 1
            return least
        if len(home.inflight) - len(least.inflight) <= self.spill_slack:
            self.counters["affinity_hits"] += 1
            return home
        self._home[key] = least.idx
        self.counters["spills"] += 1
        return least

    def _note_peak(self) -> None:
        """Track peak fleet-wide in-flight (call with lock held)."""
        total = sum(len(r.inflight) for r in self.replicas)
        if total > self.counters["peak_inflight"]:
            self.counters["peak_inflight"] = total

    def _validate_shape(self, req: DiffusionRequest) -> None:
        """Fail fast at the router boundary: a request whose declared
        shape is outside the fleet's ladder raises
        :class:`ShapeMismatchError` synchronously — before ``submitted``
        is counted, so ``submitted == resolved + failed`` holds without
        a round-trip to a replica (whose own scheduler would reject it
        anyway, but only after pickling + a pipe hop).  Skipped when the
        workers predate shape metadata."""
        if self._shape_ladder is None and self._default_shape is None:
            return
        validate_request_shape(req, self._default_shape,
                               self._shape_ladder)

    # --- submit path -----------------------------------------------------
    def submit(self, req: DiffusionRequest) -> Future:
        """Thread-safe; the future resolves to this request's
        ``DiffusionResult`` from whichever replica serves it (survivors
        included, if its first home dies mid-flight).  Blocks while
        every healthy replica is at ``max_inflight`` (after shedding
        quality once, if ``shed_factor`` is set).  Raises
        ``ShapeMismatchError`` for shapes outside the fleet's declared
        ladder — synchronously, before the request is counted."""
        fut: Future = Future()
        with self._cv:
            if not self._started:
                raise RuntimeError("router not started; call start()")
            self._validate_shape(req)
            blocked = shed = False
            while True:
                if self._stopping:
                    raise RuntimeError("router has been shut down")
                try:
                    r = self._route(req, respect_cap=True)
                    break
                except RuntimeError:
                    # nothing routable right now: at capacity, on
                    # probation, or awaiting a supervisor restart —
                    # block unless nobody is healthy AND nobody can
                    # ever come back
                    if not any(x.healthy for x in self.replicas) \
                            and not (self.supervisor is not None
                                     and self.supervisor.can_recover()):
                        raise RuntimeError("no healthy replicas") from None
                if not blocked:
                    blocked = True
                    self.counters["backpressure_waits"] += 1
                if self.shed_factor and not shed \
                        and req.max_error is not None:
                    # quality shed: one-shot budget relaxation beats an
                    # unbounded queue (coarser result now > timeout later)
                    req = dataclasses.replace(
                        req, max_error=req.max_error * self.shed_factor)
                    self.counters["router_shed_events"] += 1
                    shed = True
                self._cv.wait(0.05)
            self.counters["submitted"] += 1
            token = self._next_token
            self._next_token += 1
            r.inflight[token] = (req, fut, 0)
            self._note_peak()
        self._send_submit(r, token, req)
        return fut

    def _send_submit(self, r: Replica, token: int,
                     req: DiffusionRequest) -> None:
        try:
            r.send(("submit", token, _wire_request(req)))
        except (OSError, ValueError, BrokenPipeError):
            # the pipe died between routing and sending: run the death
            # path ourselves (idempotent) so this token is requeued too
            self._on_replica_down(r)

    def pending(self) -> int:
        with self._lock:
            return (sum(len(r.inflight) for r in self.replicas)
                    + len(self._parked))

    # --- receive / failure paths -----------------------------------------
    def _recv_loop(self, r: Replica) -> None:
        while True:
            try:
                msg = r.conn.recv()
            except (EOFError, OSError):
                break
            kind = msg[0]
            if kind == "result":
                self._finish(r, msg[1], value=msg[2])
            elif kind == "error":
                self._finish(r, msg[1], exc=msg[2])
            elif kind == "pong":
                r.last_pong = time.monotonic()
            elif kind == "metrics":
                r.metrics_box.append(msg[1])
                r.metrics_event.set()
            elif kind == "stopping":
                with self._lock:
                    r.stopped = True
                    r.healthy = False
        # EOF only after the buffer is drained: any result that raced a
        # crash has already resolved its future above
        self._on_replica_down(r)

    def _finish(self, r: Replica, token: int, value=None, exc=None) -> None:
        with self._cv:
            entry = r.inflight.pop(token, None)
            if entry is not None:
                self.counters["resolved" if exc is None else "failed"] += 1
                if getattr(r, "probation", False) and not r.inflight:
                    # the isolation probe came back: the replica
                    # survived, the request was a bystander — release
                    # the replica back into the routable pool
                    r.probation = False
            self._cv.notify_all()
        if entry is None:
            return                      # requeued or cancelled meanwhile
        fut = entry[1]
        if fut.cancelled():
            return
        try:
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(value)
        except InvalidStateError:       # exactly-once guard, observable
            with self._lock:
                self.counters["duplicate_results"] += 1

    def _on_replica_down(self, r: Replica) -> None:
        """Mark ``r`` unhealthy and re-place its in-flight work: requeue
        under budget, quarantine solo killers at budget, park ambiguous
        cohort members for an isolation probe.  Idempotent; safe to call
        from any thread."""
        with self._cv:
            was_healthy = r.healthy
            r.healthy = False
            orphans = list(r.inflight.items())
            r.inflight.clear()
            if was_healthy and not r.stopped and not self._stopping:
                self.counters["replicas_lost"] += 1
            self._cv.notify_all()
        if self._stopping:
            for _, entry in orphans:
                entry[1].cancel()
            return
        solo = len(orphans) == 1
        for _, entry in orphans:
            req, fut = entry[0], entry[1]
            deaths = _entry_deaths(entry) + 1
            if fut.cancelled():
                continue
            if deaths >= self.retry_budget:
                if solo:
                    # unambiguous: it was alone on the replica it killed
                    with self._lock:
                        self.counters["poison_quarantined"] += 1
                    try:
                        fut.set_exception(PoisonRequestError(
                            f"request implicated solo in {deaths} replica "
                            f"deaths (budget {self.retry_budget}); "
                            "quarantined"))
                    except InvalidStateError:
                        pass
                    else:
                        with self._lock:
                            self.counters["failed"] += 1
                    continue
                # ambiguous: it died in a cohort — any member could be
                # the poison, so isolate instead of quarantining a
                # possibly-healthy bystander
                with self._cv:
                    self._parked.append([req, fut, deaths, True])
                    self.counters["probations"] += 1
                    self._cv.notify_all()
                continue
            self._requeue(req, fut, deaths)
        self._place_parked()

    def _requeue(self, req: DiffusionRequest, fut: Future,
                 deaths: int) -> None:
        """Re-place one orphan on a survivor; park it while recovery is
        possible, fail it only when no replica can ever come back."""
        try:
            with self._lock:
                nr = self._route(req)
                ntoken = self._next_token
                self._next_token += 1
                nr.inflight[ntoken] = (req, fut, deaths)
                self.counters["requeued"] += 1
                self._note_peak()
        except RuntimeError as e:       # no healthy replicas right now
            if self.supervisor is not None and self.supervisor.can_recover():
                with self._cv:
                    self._parked.append([req, fut, deaths, False])
                    self._cv.notify_all()
                return
            try:
                fut.set_exception(e)
            except InvalidStateError:
                pass
            else:
                with self._lock:
                    self.counters["failed"] += 1
            return
        self._send_submit(nr, ntoken, req)

    def _place_parked(self) -> None:
        """Try to place parked work: isolation probes onto an idle
        replica (flagged ``probation``), plain orphans onto any healthy
        survivor.  Called on monitor/drain ticks and at adoption."""
        placed = []
        with self._cv:
            if self._stopping or not self._parked:
                return
            doomed = []
            if not any(r.healthy for r in self.replicas) and (
                    self.supervisor is None
                    or not self.supervisor.can_recover()):
                # every slot is dead or retired: parked work can never
                # be placed — fail it instead of holding futures forever
                doomed, self._parked = self._parked, []
                self._cv.notify_all()
            remaining = []
            for entry in self._parked:
                req, fut, deaths, probe = entry
                if fut.cancelled():
                    continue
                if probe:
                    # probes must run SOLO: an idle, routable replica
                    cand = next(
                        (r for r in self.replicas
                         if r.healthy and not getattr(r, "probation", False)
                         and not r.inflight), None)
                    if cand is None:
                        remaining.append(entry)
                        continue
                    cand.probation = True
                    token = self._next_token
                    self._next_token += 1
                    cand.inflight[token] = (req, fut, deaths)
                    placed.append((cand, token, req))
                else:
                    try:
                        nr = self._route(req)
                    except RuntimeError:
                        remaining.append(entry)
                        continue
                    token = self._next_token
                    self._next_token += 1
                    nr.inflight[token] = (req, fut, deaths)
                    self.counters["requeued"] += 1
                    placed.append((nr, token, req))
            self._parked = remaining
            self._note_peak()
            if placed:
                self._cv.notify_all()
        for entry in doomed:
            try:
                entry[1].set_exception(RuntimeError(
                    "no healthy replicas and no recovery possible"))
            except InvalidStateError:
                pass
            else:
                with self._lock:
                    self.counters["failed"] += 1
        for r, token, req in placed:
            self._send_submit(r, token, req)

    def _monitor(self) -> None:
        seq = 0
        while not self._stop_monitor.wait(self.health_interval_s):
            self._place_parked()
            for r in self.replicas:
                if not r.healthy or getattr(r, "kill_requested", False):
                    continue
                seq += 1
                try:
                    r.send(("ping", seq))
                except (OSError, ValueError, BrokenPipeError):
                    continue            # receiver thread handles the EOF
                stale = time.monotonic() - r.last_pong
                if stale > self.stale_after_s:
                    # alive-but-unresponsive: kill once (latched), so the
                    # EOF path (buffer-drain then requeue) takes over
                    if r.kill():
                        with self._lock:
                            self.counters["stale_pong_kills"] += 1

    # --- drain / shutdown ------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every future submitted so far has resolved —
        parked work included, so a drain rides out a mid-stream replica
        restart.  Re-sends the flush on each wait tick, so partial
        batches formed *during* the drain are cut too.  False on
        timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            self._place_parked()
            with self._lock:
                replicas = [r for r in self.replicas if r.healthy]
            for r in replicas:
                try:
                    r.send(("drain",))
                except (OSError, ValueError, BrokenPipeError):
                    pass
            with self._cv:
                if not any(r.inflight for r in self.replicas) \
                        and not self._parked:
                    return True
                wait = 0.25
                if deadline is not None:
                    wait = min(wait, deadline - time.monotonic())
                    if wait <= 0:
                        return False
                self._cv.wait(wait)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the fleet.  ``drain=True`` serves everything already
        submitted first; ``drain=False`` cancels outstanding futures and
        terminates the workers.  Idempotent."""
        if drain and self._started and not self._stopping:
            self.drain(timeout)
        if self.supervisor is not None:
            self.supervisor.stop()      # no restarts while we tear down
        with self._lock:
            self._stopping = True
            orphans = [entry for r in self.replicas
                       for entry in r.inflight.values()]
            orphans += self._parked
            self._parked = []
            for r in self.replicas:
                r.inflight.clear()
                r.healthy = False
        self._stop_monitor.set()
        for entry in orphans:
            entry[1].cancel()
        for r in self.replicas:
            try:
                r.send(("stop",))
            except (OSError, ValueError, BrokenPipeError):
                pass
        join_s = 30.0 if timeout is None else timeout
        for r in self.replicas:
            r.proc.join(join_s)
            if r.proc.is_alive():
                r.kill()
                r.proc.join(5.0)

    # --- observability ---------------------------------------------------
    def status(self) -> Dict:
        with self._lock:
            out = {
                "replicas": [{
                    "idx": r.idx,
                    "pid": r.meta.get("pid"),
                    "alive": r.proc.is_alive(),
                    "healthy": r.healthy,
                    "probation": getattr(r, "probation", False),
                    "start_n": getattr(r, "start_n", 0),
                    "inflight": len(r.inflight),
                    "last_pong_age_s": round(
                        time.monotonic() - r.last_pong, 3),
                } for r in self.replicas],
                "healthy_replicas": sum(r.healthy for r in self.replicas),
                "parked": len(self._parked),
                "counters": dict(self.counters),
            }
        if self.supervisor is not None:
            out["supervisor"] = self.supervisor.state()
        return out

    def replica_metrics(self, timeout: float = 30.0) -> Dict[int, dict]:
        """Latest ``ServeMetrics.to_dict()`` snapshot per live replica."""
        with self._lock:
            replicas = [r for r in self.replicas if r.healthy]
        for r in replicas:
            r.metrics_event.clear()
            try:
                r.send(("metrics",))
            except (OSError, ValueError, BrokenPipeError):
                pass
        out: Dict[int, dict] = {}
        for r in replicas:
            if r.metrics_event.wait(timeout) and r.metrics_box:
                out[r.idx] = r.metrics_box[-1]
        return out

    def fleet_metrics(self, timeout: float = 30.0) -> FleetMetrics:
        """Fleet-wide aggregation: merged ``ServeMetrics`` + per-replica
        occupancy/recompile breakdown + routing-decision counters (and
        supervision counters, when the supervisor is running).  The
        router's own wire-format counters ride along as ``router_snap``
        so they merge into the fleet ``ServeMetrics``."""
        snaps = self.replica_metrics(timeout)
        with self._lock:
            routing = dict(self.counters)
            meta = {r.idx: dict(r.meta) for r in self.replicas}
            router_snap = {
                "duplicate_results": self.counters["duplicate_results"],
                "stale_pong_kills": self.counters["stale_pong_kills"],
            }
        if self.supervisor is not None:
            sup = self.supervisor.state()
            routing.update({k: sup[k] for k in
                            ("restarts", "boot_failures",
                             "replicas_retired", "restart_backoff_s")})
        return FleetMetrics(snaps, routing=routing, meta=meta,
                            router_snap=router_snap)
