"""Policy-aware request router over N engine replicas.

``FleetRouter`` is the fleet analogue of ``AsyncDiffusionEngine``:
``submit(request)`` is thread-safe and returns a
``concurrent.futures.Future`` immediately; ``drain()`` waits for
everything submitted so far (flushing partial batches on every
replica); ``shutdown(drain=True)`` stops the workers gracefully
(``drain=False`` cancels outstanding futures and terminates).  The
difference is *where* batches form: the router never cuts batches
itself — each replica runs its own ``Scheduler`` — so the router's job
is to place requests such that the per-replica schedulers still see
policy-pure streams.

**Routing rule** (compatibility-key affinity + load):  each request is
keyed by its resolved policy's ``compatibility_key()`` with the
``max_error`` budget tier folded in (``Policy.with_budget`` — the same
key the replica's scheduler groups by).  A group has a *home* replica;
requests follow their home while it stays healthy and within
``spill_slack`` outstanding requests of the least-loaded replica, so a
group's requests pile onto ONE queue and fill policy-pure buckets
fleet-wide instead of fragmenting into per-replica singles.  When the
home falls behind by more than ``spill_slack`` (default: the replica's
``max_batch`` — one full bucket of slack), the group *spills*: the
least-loaded replica becomes the new home.  New groups start on the
least-loaded replica.  Decisions are counted
(``affinity_hits`` / ``new_groups`` / ``spills`` / ``requeued``) and
reported through ``FleetMetrics``.

**Health / failure**:  a monitor thread pings every replica on
``health_interval_s``; one receiver thread per replica streams results
back and resolves futures.  A dead replica is detected by pipe EOF
(crash/SIGKILL) or a stale pong (hung worker — it is then killed so the
EOF path takes over).  Death handling runs on the receiver thread
*after* the pipe buffer is fully drained, so results that raced the
crash still resolve; everything left in the replica's in-flight map is
requeued onto the surviving replicas (sampling is deterministic per
request seed, so a re-run resolves to the same latents) and each future
still resolves exactly once.  With no survivors the orphaned futures
fail with ``RuntimeError``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional

from repro.analysis.runtime import make_condition, make_lock
from repro.serving.fleet.fleet_metrics import FleetMetrics
from repro.serving.fleet.worker import Replica
from repro.serving.scheduler import DiffusionRequest

__all__ = ["FleetRouter"]


def _wire_request(req: DiffusionRequest) -> DiffusionRequest:
    """Copy with device arrays made host-side so the request pickles."""
    if req.init_latents is None:
        return req
    import dataclasses

    import numpy as np
    return dataclasses.replace(req, init_latents=np.asarray(req.init_latents))


class FleetRouter:
    """Frontend over N replica processes (see module docstring).

    ``factory`` must be a picklable zero-arg callable returning an
    (unwarmed) ``DiffusionEngine`` — a module-level function or a
    ``functools.partial`` of one; each worker calls it in its own
    process.  ``warm`` maps onto ``DiffusionEngine.warmup`` kwargs and
    runs once per replica at boot.  ``default_policy`` mirrors the
    engines' default and is only used to compute affinity keys for
    requests with ``policy=None``.
    """

    def __init__(self, factory, n_replicas: int = 2, warm: Optional[dict]
                 = None, default_policy=None, worker_env: Optional[dict]
                 = None, spill_slack: Optional[int] = None,
                 health_interval_s: float = 0.25,
                 stale_after_s: float = 30.0,
                 boot_timeout_s: float = 600.0):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.factory = factory
        self.n_replicas = n_replicas
        self.warm = dict(warm or {})
        self.default_policy = default_policy
        self.worker_env = dict(worker_env or {})
        self.spill_slack = spill_slack
        self.health_interval_s = health_interval_s
        self.stale_after_s = stale_after_s
        self.boot_timeout_s = boot_timeout_s

        self.replicas: List[Replica] = []
        self._lock = make_lock("FleetRouter._lock")
        self._cv = make_condition("FleetRouter._cv", lock=self._lock)
        self._home: Dict = {}         # affinity key -> replica idx
        self._key_cache: Dict = {}    # (policy, max_error) -> affinity key
        self._next_token = 0
        self._stopping = False
        self._started = False
        self._stop_monitor = threading.Event()
        self._threads: List[threading.Thread] = []
        self.counters: Dict[str, int] = {
            "submitted": 0, "resolved": 0, "failed": 0,
            "affinity_hits": 0, "new_groups": 0, "spills": 0,
            "requeued": 0, "replicas_lost": 0, "duplicate_results": 0,
        }

    # --- lifecycle -------------------------------------------------------
    def start(self) -> "FleetRouter":
        """Spawn all replicas (they boot + warm in parallel), wait until
        every one is ready, then start the receiver/monitor threads."""
        with self._lock:
            if self._stopping:
                raise RuntimeError("router has been shut down")
            if self._started:
                return self
            self._started = True
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        self.replicas = [
            Replica(i, self.factory, warm=self.warm, env=self.worker_env,
                    ctx=ctx)
            for i in range(self.n_replicas)]
        deadline = time.monotonic() + self.boot_timeout_s
        try:
            for r in self.replicas:
                r.wait_ready(max(deadline - time.monotonic(), 0.1))
        except BaseException:
            for r in self.replicas:
                r.kill()
            raise
        if self.spill_slack is None:
            self.spill_slack = max(r.meta.get("max_batch", 1)
                                   for r in self.replicas)
        for r in self.replicas:
            th = threading.Thread(target=self._recv_loop, args=(r,),
                                  name=f"fleet-recv-{r.idx}", daemon=True)
            th.start()
            self._threads.append(th)
        mon = threading.Thread(target=self._monitor, name="fleet-monitor",
                               daemon=True)
        mon.start()
        self._threads.append(mon)
        return self

    def __enter__(self) -> "FleetRouter":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    # --- routing ---------------------------------------------------------
    def _affinity_key(self, req: DiffusionRequest):
        """The compatibility-group key the replica's scheduler will file
        this request under: resolved policy, budget tier folded in."""
        pol = req.policy if req.policy is not None else self.default_policy
        ck = (pol, req.max_error)
        key = self._key_cache.get(ck)
        if key is None:
            if pol is None:
                key = ("default", req.max_error)
            else:
                from repro.core.policies import registry
                key = registry.compatibility_key(
                    registry.resolve(pol).with_budget(req.max_error))
            self._key_cache[ck] = key
        return key

    def _route(self, req: DiffusionRequest) -> Replica:
        """Pick a replica (call with ``self._lock`` held)."""
        healthy = [r for r in self.replicas if r.healthy]
        if not healthy:
            raise RuntimeError("no healthy replicas")
        key = self._affinity_key(req)
        least = min(healthy, key=lambda r: (len(r.inflight), r.idx))
        idx = self._home.get(key)
        home = next((r for r in healthy if r.idx == idx), None)
        if home is None:
            self._home[key] = least.idx
            self.counters["new_groups"] += 1
            return least
        if len(home.inflight) - len(least.inflight) <= self.spill_slack:
            self.counters["affinity_hits"] += 1
            return home
        self._home[key] = least.idx
        self.counters["spills"] += 1
        return least

    # --- submit path -----------------------------------------------------
    def submit(self, req: DiffusionRequest) -> Future:
        """Thread-safe; the future resolves to this request's
        ``DiffusionResult`` from whichever replica serves it (survivors
        included, if its first home dies mid-flight)."""
        fut: Future = Future()
        with self._lock:
            if self._stopping:
                raise RuntimeError("router has been shut down")
            if not self._started:
                raise RuntimeError("router not started; call start()")
            self.counters["submitted"] += 1
            r = self._route(req)
            token = self._next_token
            self._next_token += 1
            r.inflight[token] = (req, fut)
        self._send_submit(r, token, req)
        return fut

    def _send_submit(self, r: Replica, token: int,
                     req: DiffusionRequest) -> None:
        try:
            r.send(("submit", token, _wire_request(req)))
        except (OSError, ValueError, BrokenPipeError):
            # the pipe died between routing and sending: run the death
            # path ourselves (idempotent) so this token is requeued too
            self._on_replica_down(r)

    def pending(self) -> int:
        with self._lock:
            return sum(len(r.inflight) for r in self.replicas)

    # --- receive / failure paths -----------------------------------------
    def _recv_loop(self, r: Replica) -> None:
        while True:
            try:
                msg = r.conn.recv()
            except (EOFError, OSError):
                break
            kind = msg[0]
            if kind == "result":
                self._finish(r, msg[1], value=msg[2])
            elif kind == "error":
                self._finish(r, msg[1], exc=msg[2])
            elif kind == "pong":
                r.last_pong = time.monotonic()
            elif kind == "metrics":
                r.metrics_box.append(msg[1])
                r.metrics_event.set()
            elif kind == "stopping":
                with self._lock:
                    r.stopped = True
                    r.healthy = False
        # EOF only after the buffer is drained: any result that raced a
        # crash has already resolved its future above
        self._on_replica_down(r)

    def _finish(self, r: Replica, token: int, value=None, exc=None) -> None:
        with self._cv:
            entry = r.inflight.pop(token, None)
            if entry is not None:
                self.counters["resolved" if exc is None else "failed"] += 1
            self._cv.notify_all()
        if entry is None:
            return                      # requeued or cancelled meanwhile
        fut = entry[1]
        if fut.cancelled():
            return
        try:
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(value)
        except InvalidStateError:       # exactly-once guard, observable
            with self._lock:
                self.counters["duplicate_results"] += 1

    def _on_replica_down(self, r: Replica) -> None:
        """Mark ``r`` unhealthy and requeue its in-flight work onto the
        survivors.  Idempotent; safe to call from any thread."""
        with self._cv:
            was_healthy = r.healthy
            r.healthy = False
            orphans = list(r.inflight.items())
            r.inflight.clear()
            if was_healthy and not r.stopped and not self._stopping:
                self.counters["replicas_lost"] += 1
            self._cv.notify_all()
        if self._stopping:
            for _, (_, fut) in orphans:
                fut.cancel()
            return
        for token, (req, fut) in orphans:
            if fut.cancelled():
                continue
            try:
                with self._lock:
                    nr = self._route(req)
                    ntoken = self._next_token
                    self._next_token += 1
                    nr.inflight[ntoken] = (req, fut)
                    self.counters["requeued"] += 1
            except RuntimeError as e:   # no healthy replicas left
                try:
                    fut.set_exception(e)
                except InvalidStateError:
                    pass
                continue
            self._send_submit(nr, ntoken, req)

    def _monitor(self) -> None:
        seq = 0
        while not self._stop_monitor.wait(self.health_interval_s):
            for r in self.replicas:
                if not r.healthy:
                    continue
                seq += 1
                try:
                    r.send(("ping", seq))
                except (OSError, ValueError, BrokenPipeError):
                    continue            # receiver thread handles the EOF
                stale = time.monotonic() - r.last_pong
                if stale > self.stale_after_s:
                    # alive-but-unresponsive: kill, so the EOF path
                    # (buffer-drain then requeue) takes over cleanly
                    r.kill()

    # --- drain / shutdown ------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every future submitted so far has resolved.
        Re-sends the flush on each wait tick, so partial batches formed
        *during* the drain are cut too.  False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                replicas = [r for r in self.replicas if r.healthy]
            for r in replicas:
                try:
                    r.send(("drain",))
                except (OSError, ValueError, BrokenPipeError):
                    pass
            with self._cv:
                if not any(r.inflight for r in self.replicas):
                    return True
                wait = 0.25
                if deadline is not None:
                    wait = min(wait, deadline - time.monotonic())
                    if wait <= 0:
                        return False
                self._cv.wait(wait)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the fleet.  ``drain=True`` serves everything already
        submitted first; ``drain=False`` cancels outstanding futures and
        terminates the workers.  Idempotent."""
        if drain and self._started and not self._stopping:
            self.drain(timeout)
        with self._lock:
            self._stopping = True
            orphans = [entry for r in self.replicas
                       for entry in r.inflight.values()]
            for r in self.replicas:
                r.inflight.clear()
                r.healthy = False
        self._stop_monitor.set()
        for _, fut in orphans:
            fut.cancel()
        for r in self.replicas:
            try:
                r.send(("stop",))
            except (OSError, ValueError, BrokenPipeError):
                pass
        join_s = 30.0 if timeout is None else timeout
        for r in self.replicas:
            r.proc.join(join_s)
            if r.proc.is_alive():
                r.kill()
                r.proc.join(5.0)

    # --- observability ---------------------------------------------------
    def status(self) -> Dict:
        with self._lock:
            return {
                "replicas": [{
                    "idx": r.idx,
                    "pid": r.meta.get("pid"),
                    "alive": r.proc.is_alive(),
                    "healthy": r.healthy,
                    "inflight": len(r.inflight),
                    "last_pong_age_s": round(
                        time.monotonic() - r.last_pong, 3),
                } for r in self.replicas],
                "healthy_replicas": sum(r.healthy for r in self.replicas),
                "counters": dict(self.counters),
            }

    def replica_metrics(self, timeout: float = 30.0) -> Dict[int, dict]:
        """Latest ``ServeMetrics.to_dict()`` snapshot per live replica."""
        with self._lock:
            replicas = [r for r in self.replicas if r.healthy]
        for r in replicas:
            r.metrics_event.clear()
            try:
                r.send(("metrics",))
            except (OSError, ValueError, BrokenPipeError):
                pass
        out: Dict[int, dict] = {}
        for r in replicas:
            if r.metrics_event.wait(timeout) and r.metrics_box:
                out[r.idx] = r.metrics_box[-1]
        return out

    def fleet_metrics(self, timeout: float = 30.0) -> FleetMetrics:
        """Fleet-wide aggregation: merged ``ServeMetrics`` + per-replica
        occupancy/recompile breakdown + routing-decision counters."""
        snaps = self.replica_metrics(timeout)
        with self._lock:
            routing = dict(self.counters)
            meta = {r.idx: dict(r.meta) for r in self.replicas}
        return FleetMetrics(snaps, routing=routing, meta=meta)
