"""Deterministic fault injection at the ``Replica``/pipe boundary.

A ``FaultInjector`` holds *scripted* rules keyed by ``(slot, start_n)``
— the replica slot index and which incarnation of that slot is booting
(0 = initial boot, 1 = first supervisor restart, ...).  The router
calls :meth:`spec_for` once per spawn and ships the resulting plain
dict to the child alongside the factory payload; ``worker_main``
consults it at the matching protocol points:

``boot_fail``
    The worker reports ``("boot_error", ...)`` and exits before
    touching the factory — the never-became-ready case the router's
    boot-cleanup and the supervisor's backoff path must absorb.
``boot_hang_s``
    The worker sleeps *before* sending ``ready`` (and before loading
    the factory payload, so the hang is prompt and cheap) — the
    boot-timeout case.
``kill_after_submits``
    ``os._exit`` the instant the N-th ``submit`` command arrives —
    byte-for-byte the SIGKILL crash case (no drain, no goodbye, the
    pipe just EOFs) but deterministic in the request stream.
``kill_on_request_id``
    ``os._exit`` on receipt of the submit carrying this
    ``request_id`` — a *poison request*: every replica it reaches
    dies, which is exactly what the router's retry budget and
    quarantine must contain.
``ignore_pings_after``
    Stop answering pings after the N-th — the alive-but-hung worker
    the monitor's stale-pong kill exists for.  The worker keeps
    serving; only its health channel goes dark.
``result_delay_s``
    Sleep before each result send — delayed delivery, for racing the
    death path against late results.

Everything is deterministic: rules are scripted, and the only sampled
quantity (the optional delivery-delay jitter) is drawn from a
``random.Random`` seeded by ``(seed, slot, start_n)``, so the same
injector configuration replays the same fault schedule run after run.
"""
from __future__ import annotations

import random
from typing import List, Optional, Tuple

__all__ = ["FaultInjector"]


class FaultInjector:
    """Scripted fault plan for a fleet; see module docstring.

    Rule methods return ``self`` so plans chain::

        faults = (FaultInjector(seed=0)
                  .kill_after_submits(3, slot=0, start_n=0)
                  .fail_boot(slot=0, start_n=1))

    ``slot=None`` / ``start_n=None`` match every slot / incarnation.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        # (slot | None, start_n | None, spec key, value)
        self._rules: List[Tuple[Optional[int], Optional[int], str,
                                object]] = []

    def _add(self, slot: Optional[int], start_n: Optional[int],
             key: str, value) -> "FaultInjector":
        self._rules.append((slot, start_n, key, value))
        return self

    # --- boot faults -----------------------------------------------------
    def fail_boot(self, slot: Optional[int] = None,
                  start_n: Optional[int] = None) -> "FaultInjector":
        """Worker reports ``boot_error`` instead of becoming ready."""
        return self._add(slot, start_n, "boot_fail", True)

    def hang_boot(self, hang_s: float, slot: Optional[int] = None,
                  start_n: Optional[int] = None) -> "FaultInjector":
        """Worker sleeps ``hang_s`` before ``ready`` (boot timeout)."""
        return self._add(slot, start_n, "boot_hang_s", float(hang_s))

    # --- crash faults ----------------------------------------------------
    def kill_after_submits(self, n: int, slot: Optional[int] = None,
                           start_n: Optional[int] = None
                           ) -> "FaultInjector":
        """Worker ``os._exit``\\ s when its ``n``-th submit arrives."""
        return self._add(slot, start_n, "kill_after_submits", int(n))

    def kill_on_request(self, request_id: int,
                        slot: Optional[int] = None,
                        start_n: Optional[int] = None) -> "FaultInjector":
        """Worker dies on receipt of this request — a poison request."""
        return self._add(slot, start_n, "kill_on_request_id",
                         int(request_id))

    # --- hang / delay faults ---------------------------------------------
    def mute_pings_after(self, n: int, slot: Optional[int] = None,
                         start_n: Optional[int] = None) -> "FaultInjector":
        """Worker stops ponging after its ``n``-th ping (hung-alive)."""
        return self._add(slot, start_n, "ignore_pings_after", int(n))

    def delay_results(self, delay_s: float, jitter_s: float = 0.0,
                      slot: Optional[int] = None,
                      start_n: Optional[int] = None) -> "FaultInjector":
        """Sleep before each result send (+ seeded deterministic
        jitter), delaying delivery without harming the worker."""
        return self._add(slot, start_n, "result_delay_s",
                         (float(delay_s), float(jitter_s)))

    # --- resolution ------------------------------------------------------
    def spec_for(self, slot: int, start_n: int) -> dict:
        """The fault spec one spawn of ``slot``'s ``start_n``-th
        incarnation should carry: a plain picklable dict (later rules
        win on key collisions).  Deterministic in (seed, slot,
        start_n)."""
        spec: dict = {}
        for s, n, key, value in self._rules:
            if (s is not None and s != slot) or \
                    (n is not None and n != start_n):
                continue
            if key == "result_delay_s":
                base, jitter = value
                if jitter:
                    rng = random.Random(
                        self.seed * 1_000_003 + slot * 1_009 + start_n)
                    base += rng.uniform(0.0, jitter)
                value = base
            spec[key] = value
        return spec
