"""One engine replica per child process, behind a command pipe.

``worker_main`` is the child entry point: it applies per-replica env
overrides *before* importing jax (so a fleet can pin threads or
platform per worker), builds its ``DiffusionEngine`` from a pickled
zero-arg factory, warms the bucket ladder, wraps the engine in
``AsyncDiffusionEngine``, and then serves a tiny command protocol over
one duplex ``multiprocessing.connection`` pipe:

    ("submit", token, request)  -> ("result", token, DiffusionResult)
                                 | ("error", token, exception)
    ("ping", seq)               -> ("pong", seq, {depth, pending})
    ("metrics",)                -> ("metrics", ServeMetrics.to_dict())
    ("drain",)                  -> ("drained",)   (flushes partial batches)
    ("stop",) / SIGTERM         -> graceful drain, ("stopping",), exit

Results stream back *as batches complete* — the worker attaches a
done-callback to each future, so the command loop never blocks on
device work and pings stay answered while a batch executes.  SIGTERM is
a graceful drain: everything already queued is served before the
process exits (a SIGKILL is the crash case the router's requeue path
covers).  All sends share one lock; the loop polls so the SIGTERM flag
is observed promptly.

``Replica`` is the parent-side handle: it spawns the process (spawn
context — never fork a process that already holds jax threads), owns
the parent end of the pipe, and carries the router's per-replica
bookkeeping (in-flight map, health flag, boot metadata).

For chaos testing, ``worker_main`` takes an optional ``fault`` spec
(a plain dict produced by ``FaultInjector.spec_for``) as a *separate*
process argument — separate because boot faults must fire before
``pickle.loads(payload)`` pulls in the factory's module (and jax),
keeping injected boot failures cheap and prompt.
"""
from __future__ import annotations

import os
import pickle
import signal
import threading
import time
import traceback

from repro.analysis.runtime import make_lock

__all__ = ["Replica", "worker_main"]


def _wire_exc(e: BaseException) -> BaseException:
    """The exception itself when picklable, else a carrier with its text."""
    try:
        pickle.dumps(e)
        return e
    except Exception:
        return RuntimeError(f"{type(e).__name__}: {e}")


def worker_main(conn, env: dict, payload: bytes, fault=None) -> None:
    """Child-process entry: build, warm, serve until stop/SIGTERM.

    ``payload`` is ``pickle.dumps((factory, warm))`` — deferred so the
    factory's module (and therefore jax) is imported only after ``env``
    is applied.  ``warm`` maps straight onto ``DiffusionEngine.warmup``
    kwargs (``buckets`` / ``policies`` / ``lane_policy_sets``).

    ``fault`` is an optional scripted-fault spec (see ``faults.py``);
    ``None`` in production.
    """
    os.environ.update(env)
    fault = dict(fault or {})
    stop_flag = threading.Event()
    try:
        # SIGTERM = graceful drain (the router's polite shutdown and any
        # process supervisor's default); SIGKILL remains the crash case
        signal.signal(signal.SIGTERM, lambda s, f: stop_flag.set())
    except ValueError:
        pass

    # injected boot faults fire before the payload is even unpickled —
    # the parent must handle never-ready workers however early they die
    if fault.get("boot_hang_s"):
        time.sleep(float(fault["boot_hang_s"]))
    if fault.get("boot_fail"):
        try:
            conn.send(("boot_error", "injected boot failure"))
        finally:
            conn.close()
        return

    try:
        factory, warm = pickle.loads(payload)
        engine = factory()
        warm = dict(warm or {})
        warm_s = engine.warmup(
            buckets=warm.get("buckets"),
            lane_policy_sets=warm.get("lane_policy_sets", ()),
            policies=warm.get("policies", ()),
            shapes=[tuple(map(tuple, s))
                    for s in warm.get("shapes", ())])
        warm_compiles = engine.metrics_dict()["compile_misses"]
        from repro.serving.async_engine import AsyncDiffusionEngine
        aeng = AsyncDiffusionEngine(engine).start()
    except BaseException:
        try:
            conn.send(("boot_error", traceback.format_exc()))
        finally:
            conn.close()
        return

    import numpy as np
    send_lock = make_lock("worker.send_lock")

    def send(msg) -> None:
        with send_lock:
            try:
                conn.send(msg)
            except (OSError, ValueError, BrokenPipeError):
                pass            # router is gone; keep draining regardless

    result_delay_s = float(fault.get("result_delay_s") or 0.0)

    def on_done(token: int):
        # runs on the async engine's worker thread the moment the
        # request's batch finishes — results stream, commands never wait
        def cb(fut):
            if result_delay_s:
                time.sleep(result_delay_s)
            try:
                res = fut.result()
            except BaseException as e:
                send(("error", token, _wire_exc(e)))
            else:
                send(("result", token,
                      res._replace(latents=np.asarray(res.latents))))
        return cb

    send(("ready", {
        "pid": os.getpid(),
        "warmup_s": warm_s,
        "warmup_compiles": warm_compiles,
        "max_batch": engine.max_batch,
        "buckets": list(engine.buckets),
        # shape ladder: lists (not tuples) so the wire dict stays plain;
        # the router re-tuples before validating submits against it
        "shapes": [[list(lat), list(crf)] for lat, crf in engine.shapes],
        "default_shape": [list(engine.latent_shape),
                          list(engine.crf_shape)],
    }))

    kill_after_submits = int(fault.get("kill_after_submits") or 0)
    kill_on_request_id = fault.get("kill_on_request_id")
    ignore_pings_after = int(fault.get("ignore_pings_after") or 0)
    submits_seen = pings_seen = 0

    # at most one drain flusher in flight: FleetRouter.drain() re-sends
    # ("drain",) every tick, and each used to spawn a fresh thread
    drain_thread: list = [None]

    def drain_and_ack() -> None:
        try:
            aeng.drain()
            send(("drained",))
        finally:
            drain_thread[0] = None

    while not stop_flag.is_set():
        if not conn.poll(0.1):
            continue
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break               # router vanished: drain what we have, exit
        cmd = msg[0]
        if cmd == "submit":
            _, token, req = msg
            submits_seen += 1
            # injected crash: die exactly like SIGKILL would — no drain,
            # no goodbye message, the parent just sees the pipe EOF
            if (kill_after_submits and submits_seen >= kill_after_submits) \
                    or (kill_on_request_id is not None
                        and getattr(req, "request_id", None)
                        == kill_on_request_id):
                os._exit(113)
            try:
                fut = aeng.submit(req)
            except BaseException as e:
                send(("error", token, _wire_exc(e)))
                continue
            fut.add_done_callback(on_done(token))
        elif cmd == "ping":
            pings_seen += 1
            if ignore_pings_after and pings_seen > ignore_pings_after:
                continue        # injected hang: alive but silent
            send(("pong", msg[1], {"depth": engine.scheduler.depth,
                                   "pending": aeng.pending()}))
        elif cmd == "metrics":
            send(("metrics", engine.metrics_dict()))
        elif cmd == "drain":
            # flush partial batches off the command loop so pings keep
            # flowing while the tail drains
            t = drain_thread[0]
            if t is None or not t.is_alive():
                t = threading.Thread(target=drain_and_ack,
                                     name="fleet-worker-drain", daemon=True)
                drain_thread[0] = t
                t.start()
        elif cmd == "stop":
            break

    try:
        aeng.shutdown(drain=True)       # graceful: serve the queue first
    except BaseException:
        pass
    send(("stopping",))
    conn.close()


class Replica:
    """Parent-side handle: spawned process + pipe + router bookkeeping."""

    def __init__(self, idx: int, factory, warm=None, env=None, ctx=None,
                 fault=None, start_n: int = 0):
        if ctx is None:
            import multiprocessing as mp
            ctx = mp.get_context("spawn")
        parent_conn, child_conn = ctx.Pipe()
        payload = pickle.dumps((factory, dict(warm or {})))
        self.idx = idx
        self.start_n = start_n        # which incarnation of this slot
        self.proc = ctx.Process(
            target=worker_main,
            args=(child_conn, dict(env or {}), payload, dict(fault or {})),
            name=f"fleet-replica-{idx}", daemon=True)
        self.proc.start()
        child_conn.close()
        self.conn = parent_conn
        self.send_lock = make_lock("Replica.send_lock")
        # router bookkeeping (guarded by the router's lock)
        self.inflight: dict = {}      # token -> (request, Future, deaths)
        self.healthy = False          # True from ready until death/stop
        self.stopped = False          # clean stop observed
        self.probation = False        # reserved for an isolation probe
        self.kill_requested = False   # kill() latch: fire at most once
        self.meta: dict = {}
        self.last_pong = time.monotonic()
        self.metrics_event = threading.Event()
        self.metrics_box: list = []

    def wait_ready(self, timeout: float) -> dict:
        """Block until the worker finished boot + warmup (or raise)."""
        if not self.conn.poll(timeout):
            raise TimeoutError(
                f"replica {self.idx} did not become ready in {timeout}s")
        msg = self.conn.recv()
        if msg[0] == "boot_error":
            raise RuntimeError(
                f"replica {self.idx} failed to boot:\n{msg[1]}")
        if msg[0] != "ready":
            raise RuntimeError(
                f"replica {self.idx}: expected ready, got {msg[0]!r}")
        self.meta = msg[1]
        self.healthy = True
        self.last_pong = time.monotonic()
        return self.meta

    def send(self, msg) -> None:
        """Thread-safe send (submit path, monitor pings, control)."""
        with self.send_lock:
            self.conn.send(msg)

    def kill(self) -> bool:
        """Request a hard kill; latched so repeated calls (the monitor
        re-checking a stale replica every tick) fire at most once.
        Returns True only for the call that actually issued the kill."""
        if self.kill_requested:
            return False
        self.kill_requested = True
        if self.proc.is_alive():
            self.proc.kill()
        return True

    def destroy(self, join_timeout: float = 5.0) -> None:
        """Tear the replica fully down: kill, reap, close the pipe.

        The cleanup path for workers that never became ready (boot
        timeout / ``boot_error``) and for shutdown — without the join
        the child lingers as a zombie, and without the close its pipe
        fds leak for the router's lifetime."""
        self.kill()
        try:
            self.proc.join(join_timeout)
        except Exception:
            pass
        try:
            self.conn.close()
        except Exception:
            pass
