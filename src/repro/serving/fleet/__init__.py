"""Multi-process serving fleet: N engine replicas behind a router.

The single-process stack (scheduler -> engine -> async engine) scales
to one hot process; this package is the next tier.  ``worker`` runs one
``DiffusionEngine`` + ``AsyncDiffusionEngine`` per child process behind
a stdlib ``multiprocessing.connection`` command/response channel;
``router.FleetRouter`` is the frontend that admits
``DiffusionRequest``s, routes them by policy-compatibility affinity
plus replica load (so policy-pure batches keep forming fleet-wide),
health-checks the replicas, requeues in-flight work off a dead one,
and drains/shuts down with the same semantics as
``AsyncDiffusionEngine``; ``fleet_metrics.FleetMetrics`` aggregates
per-replica ``ServeMetrics`` snapshots into fleet-wide percentiles and
per-replica/routing breakdowns.
"""
from repro.serving.fleet.fleet_metrics import FleetMetrics  # noqa: F401
from repro.serving.fleet.router import FleetRouter          # noqa: F401
from repro.serving.fleet.worker import Replica              # noqa: F401

__all__ = ["FleetMetrics", "FleetRouter", "Replica"]
