"""Multi-process serving fleet: N engine replicas behind a router.

The single-process stack (scheduler -> engine -> async engine) scales
to one hot process; this package is the next tier.  ``worker`` runs one
``DiffusionEngine`` + ``AsyncDiffusionEngine`` per child process behind
a stdlib ``multiprocessing.connection`` command/response channel;
``router.FleetRouter`` is the frontend that admits
``DiffusionRequest``s, routes them by policy-compatibility affinity
plus replica load (so policy-pure batches keep forming fleet-wide),
health-checks the replicas, requeues in-flight work off a dead one,
and drains/shuts down with the same semantics as
``AsyncDiffusionEngine``; ``fleet_metrics.FleetMetrics`` aggregates
per-replica ``ServeMetrics`` snapshots into fleet-wide percentiles and
per-replica/routing breakdowns.

The fleet is self-healing: ``supervisor.FleetSupervisor`` restarts
dead replicas with capped exponential backoff and retires
crash-loopers; the router bounds per-replica in-flight work
(backpressure with optional quality shedding), gives each request a
retry budget, and quarantines poison requests (``PoisonRequestError``)
after a solo kill or a failed isolation probe.  ``faults.FaultInjector``
is the deterministic chaos layer that exercises all of this in
``tests/test_chaos.py`` and ``benchmarks/serve_chaos.py``.
"""
from repro.serving.fleet.faults import FaultInjector        # noqa: F401
from repro.serving.fleet.fleet_metrics import FleetMetrics  # noqa: F401
from repro.serving.fleet.router import (                    # noqa: F401
    FleetRouter, PoisonRequestError)
from repro.serving.fleet.supervisor import FleetSupervisor  # noqa: F401
from repro.serving.fleet.worker import Replica              # noqa: F401

__all__ = ["FaultInjector", "FleetMetrics", "FleetRouter",
           "FleetSupervisor", "PoisonRequestError", "Replica"]
