"""Fleet-wide metric aggregation over per-replica ``ServeMetrics``.

A ``FleetMetrics`` holds the raw ``ServeMetrics.to_dict()`` snapshot of
each replica (keyed by replica index), the router's routing-decision
counters, and each replica's boot metadata.  ``merged()`` folds the
snapshots with ``ServeMetrics.merge`` — raw observations concatenate,
so the fleet p50/p95 in ``summary()["fleet"]`` are exact percentiles
over every request served anywhere, not averages of per-replica
averages.  ``summary()["per_replica"]`` keeps the per-process view the
merge erases: occupancy, request counts, and *steady-state recompiles*
(``compile_misses`` minus the warmup compiles reported in the
replica's ready metadata) — the fleet invariant is that this is 0 on
every replica once warm.  ``summary()["routing"]`` exposes the
router's decisions: affinity hits vs new groups vs spills, plus
requeue/loss accounting from the failure path.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.serving.metrics import ServeMetrics, percentile

__all__ = ["FleetMetrics"]


class FleetMetrics:
    """Aggregates per-replica snapshots; see module docstring.

    ``per_replica`` maps replica idx -> ``ServeMetrics.to_dict()``
    snapshot; ``routing`` is the router's counter dict; ``meta`` maps
    replica idx -> the worker's ready metadata (pid, warmup_s,
    warmup_compiles, max_batch, buckets).  ``router_snap`` is an
    optional partial ``ServeMetrics`` dict of counters observed on the
    router itself (``duplicate_results``, ``stale_pong_kills``) —
    events no single worker can see — folded into ``merged()`` through
    the same tolerant wire-format merge as the replica snapshots.
    """

    def __init__(self, per_replica: Dict[int, dict],
                 routing: Optional[dict] = None,
                 meta: Optional[Dict[int, dict]] = None,
                 router_snap: Optional[dict] = None):
        self.per_replica = dict(per_replica)
        self.routing = dict(routing or {})
        self.meta = dict(meta or {})
        self.router_snap = dict(router_snap or {})

    def merged(self) -> ServeMetrics:
        """One ``ServeMetrics`` over the whole fleet (exact percentiles:
        raw observation lists are concatenated, never pre-aggregated),
        router-side counters included."""
        snaps = list(self.per_replica.values())
        if self.router_snap:
            snaps = snaps + [self.router_snap]
        return ServeMetrics.merge(snaps)

    def steady_recompiles(self, idx: int) -> Optional[int]:
        """Compile misses on replica ``idx`` beyond its boot warmup —
        0 is the steady-state invariant.  None if warmup accounting is
        unavailable for this replica."""
        snap = self.per_replica.get(idx)
        warm = self.meta.get(idx, {}).get("warmup_compiles")
        if snap is None or warm is None:
            return None
        return int(snap["compile_misses"]) - int(warm)

    def summary(self) -> Dict:
        """Three sections: ``fleet`` (merged ``ServeMetrics.summary()``
        plus replica counts), ``per_replica`` (occupancy / recompile
        breakdown the merge erases), ``routing`` (decision counters)."""
        fleet = self.merged().summary()
        fleet["replicas"] = len(self.per_replica)
        per_replica = {}
        for idx, snap in sorted(self.per_replica.items()):
            occ = snap["batch_occupancy"]
            per_replica[idx] = {
                "requests": len(snap["request_latencies"]),
                "batches": len(snap["batch_walls"]),
                "mean_occupancy": round(
                    sum(occ) / max(len(occ), 1), 3),
                "request_latency_p95_s": round(
                    percentile(snap["request_latencies"], 95), 4),
                "compile_misses": snap["compile_misses"],
                "warmup_compiles": self.meta.get(idx, {}).get(
                    "warmup_compiles"),
                "steady_recompiles": self.steady_recompiles(idx),
                "compiled_signatures": snap["compiled_signatures"],
            }
        return {
            "fleet": fleet,
            "per_replica": per_replica,
            "routing": dict(self.routing),
        }
