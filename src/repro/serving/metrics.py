"""Serving metrics: queue depth, batch occupancy, latency percentiles,
full-step fraction, per-request full-step counts, time-to-first-result,
compile-cache accounting, and policy-group accounting.

Compute and quality are tracked separately now that activation is
per-lane: ``full_step_fraction`` charges every lane of a batch for each
*batch forward* (padded lanes burn the compute whenever any lane
activates), while ``request_full_steps`` records how many steps each
individual request actually activated — the per-request number that
differs across lanes in a mixed-policy batch.  The complement,
``skip_compute_fraction``, is the number the policy-homogeneous batch
former raises on mixed streams: grouped, a scheduled lane's batch only
forwards on its own schedule instead of the union of every lane's.

``compiled_signatures`` is the engine's jit-cache probe
(``DiffusionEngine.compiled_buckets()``), pushed after every warmup and
executed batch, so the grouping win — distinct signatures <=
policy-groups x buckets — is observable in ``summary()`` rather than
inferred from compile hit/miss deltas.  ``per_group`` aggregates batch
counts / served requests / occupancy per compatibility group.

One ``ServeMetrics`` instance per engine.  Recording is cheap (python
lists + counters) and thread-safe — client threads and the async
engine's worker record concurrently under one lock; ``summary()`` does
the aggregation so it can be called once at the end of a serving run or
periodically for dashboards.

Fleet aggregation rides on three methods instead of field reads:
``to_dict()`` is the lossless wire snapshot (plain lists/ints/floats,
safe to pickle across a process boundary), ``from_dict()``
reconstructs, and ``merge(parts)`` folds any number of
snapshots-or-instances into one ``ServeMetrics`` whose ``summary()``
reports true fleet-wide percentiles (raw observations are concatenated,
never pre-aggregated, so p50/p95 are exact).  ``merge`` is associative
— replicas may be merged pairwise, in any grouping — which is what lets
a router aggregate per-replica snapshots incrementally.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from repro.analysis.runtime import make_lock


def _metrics_lock() -> threading.Lock:
    """Default-factory hook: sanitizer-aware lock construction."""
    return make_lock("ServeMetrics._lock")


# snapshot schema: counters sum under merge, lists concatenate, and the
# optionals carry their own fold (min / max / sum-of-present)
_COUNTER_FIELDS = ("compile_hits", "compile_misses", "full_steps",
                   "total_steps", "budget_events_total", "shed_events",
                   "duplicate_results", "stale_pong_kills")
_LIST_FIELDS = ("batch_walls", "batch_buckets", "batch_occupancy",
                "batch_lane_spread", "request_waits", "request_latencies",
                "request_full_steps", "request_realized_errors",
                "queue_depths")
_OPTIONAL_FIELDS = ("time_to_first_result_s", "cache_state_bytes_per_lane",
                    "compiled_signatures")


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    k = max(0, min(len(ys) - 1, int(round(q / 100.0 * (len(ys) - 1)))))
    return float(ys[k])


@dataclasses.dataclass
class ServeMetrics:
    # compile cache
    compile_hits: int = 0
    compile_misses: int = 0
    # batch-level observations
    batch_walls: List[float] = dataclasses.field(default_factory=list)
    batch_buckets: List[int] = dataclasses.field(default_factory=list)
    batch_occupancy: List[float] = dataclasses.field(default_factory=list)
    batch_lane_spread: List[int] = dataclasses.field(default_factory=list)
    full_steps: int = 0
    total_steps: int = 0
    # request-level observations
    request_waits: List[float] = dataclasses.field(default_factory=list)
    request_latencies: List[float] = dataclasses.field(default_factory=list)
    request_full_steps: List[int] = dataclasses.field(default_factory=list)
    # quality SLO: per-request realized error (peak accumulated cache
    # error between full forwards, reported by error-feedback policies)
    # and the total count of budget-triggered full forwards
    request_realized_errors: List[float] = dataclasses.field(
        default_factory=list)
    budget_events_total: int = 0
    # latest scheduler shed counter (budgets relaxed under queue
    # pressure; requests are never dropped)
    shed_events: int = 0
    # queue depth samples (taken whenever the engine polls the queue)
    queue_depths: List[int] = dataclasses.field(default_factory=list)
    # futures whose second resolution was absorbed (requeue races on
    # the exactly-once path; see FleetRouter._finish / _serve)
    duplicate_results: int = 0
    # alive-but-unresponsive replicas killed by the router's monitor
    # (stale pong past stale_after_s).  Incremented router-side — the
    # latch in Replica.kill guarantees at most one per incarnation —
    # and summed across the fleet by the wire-format merge.
    stale_pong_kills: int = 0
    # async serving: seconds from serving start to the first resolved
    # result (None until observed)
    time_to_first_result_s: Optional[float] = None
    # actual per-lane cache-state footprint of the engine's policy
    # (spectral low ring included) — set once at warmup
    cache_state_bytes_per_lane: Optional[int] = None
    # latest jit-cache probe (None until pushed; -1 = probe unavailable)
    compiled_signatures: Optional[int] = None
    # per compatibility group:
    # [n_batches, n_requests, occupancy_sum, budget_events, errors]
    group_batches: Dict = dataclasses.field(default_factory=dict)
    # multi-resolution serving: per shape-key accounting
    # [n_batches, n_requests, occupancy_sum] — every batch is cut
    # shape-pure, so one key covers all its lanes
    shape_batches: Dict = dataclasses.field(default_factory=dict)
    # per-shape cache-state footprint (bytes/lane), set at warmup;
    # ``cache_state_bytes_per_lane`` stays the ladder maximum
    state_bytes_by_shape: Dict = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(
        default_factory=_metrics_lock, repr=False, compare=False)

    # --- recording -------------------------------------------------------
    def observe_compile(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.compile_hits += 1
            else:
                self.compile_misses += 1

    def observe_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depths.append(int(depth))

    def observe_first_result(self, elapsed_s: float) -> None:
        """Record time-to-first-result once (later calls are no-ops)."""
        with self._lock:
            if self.time_to_first_result_s is None:
                self.time_to_first_result_s = float(elapsed_s)

    def observe_state_bytes(self, nbytes: int,
                            shape_key: Optional[str] = None) -> None:
        """Record the engine policy's real per-lane cache footprint.
        With a ``shape_key`` the figure is also kept per ladder entry,
        and the scalar becomes the ladder maximum (the provisioning
        number for a multi-resolution deployment)."""
        with self._lock:
            if shape_key is not None:
                self.state_bytes_by_shape[str(shape_key)] = int(nbytes)
                self.cache_state_bytes_per_lane = max(
                    self.cache_state_bytes_per_lane or 0, int(nbytes))
            else:
                self.cache_state_bytes_per_lane = int(nbytes)

    def observe_compiled_signatures(self, n: int) -> None:
        """Record the engine's jit-cache probe (distinct compiled
        (bucket, lane-policy) signatures so far)."""
        with self._lock:
            self.compiled_signatures = int(n)

    def observe_shed_events(self, n: int) -> None:
        """Record the scheduler's cumulative shed counter (latest wins)."""
        with self._lock:
            self.shed_events = int(n)

    def observe_duplicate_result(self) -> None:
        """An already-resolved future was resolved again (requeue race
        on the exactly-once path); absorbed, never raised."""
        with self._lock:
            self.duplicate_results += 1

    def observe_stale_pong_kill(self) -> None:
        """A hung replica (stale pong) was killed by the monitor."""
        with self._lock:
            self.stale_pong_kills += 1

    def observe_batch(self, bucket: int, n_real: int, wall_s: float,
                      n_forwards: int, n_steps: int,
                      lane_full: Optional[List[int]] = None,
                      group_key=None,
                      lane_errors: Optional[List[float]] = None,
                      lane_events: Optional[List[int]] = None,
                      shape_key: Optional[str] = None) -> None:
        """``n_forwards`` — batch forwards actually run (compute);
        ``lane_full`` — per-real-lane activated-step counts (quality);
        ``group_key`` — the compatibility group this batch was cut from
        (None under the ungrouped former); ``lane_errors`` /
        ``lane_events`` — per-real-lane realized error and
        budget-triggered full counts from error-feedback policies;
        ``shape_key`` — the (latent, CRF) shape label of this
        (shape-pure) batch for per-resolution accounting."""
        with self._lock:
            if shape_key is not None:
                sb = self.shape_batches.setdefault(str(shape_key),
                                                   [0, 0, 0.0])
                sb[0] += 1
                sb[1] += int(n_real)
                sb[2] += n_real / max(bucket, 1)
            if group_key is not None:
                g = self.group_batches.setdefault(str(group_key),
                                                  [0, 0, 0.0, 0, []])
                g[0] += 1
                g[1] += int(n_real)
                g[2] += n_real / max(bucket, 1)
                if lane_events:
                    g[3] += int(sum(lane_events))
                if lane_errors:
                    g[4].extend(float(e) for e in lane_errors)
            if lane_full:
                # spread across lanes of one batch: 0 under a batch-global
                # decision, > 0 once lanes follow their own schedules
                self.batch_lane_spread.append(
                    max(lane_full) - min(lane_full))
            self.batch_walls.append(float(wall_s))
            self.batch_buckets.append(int(bucket))
            self.batch_occupancy.append(n_real / max(bucket, 1))
            # every lane (padded included) burns the compute of each batch
            # forward, so the compute fraction is forwards-based
            self.full_steps += int(n_forwards) * int(bucket)
            self.total_steps += int(n_steps) * int(bucket)

    def observe_request(self, wait_s: float, latency_s: float,
                        n_full: Optional[int] = None,
                        realized_error: Optional[float] = None,
                        budget_events: Optional[int] = None) -> None:
        with self._lock:
            self.request_waits.append(float(wait_s))
            self.request_latencies.append(float(latency_s))
            if n_full is not None:
                self.request_full_steps.append(int(n_full))
            if realized_error is not None:
                self.request_realized_errors.append(float(realized_error))
            if budget_events is not None:
                self.budget_events_total += int(budget_events)

    # --- aggregation -----------------------------------------------------
    @property
    def n_requests(self) -> int:
        return len(self.request_latencies)

    @property
    def n_batches(self) -> int:
        return len(self.batch_walls)

    def full_step_fraction(self) -> float:
        return self.full_steps / max(self.total_steps, 1)

    def summary(self) -> Dict:
        with self._lock:
            walls = list(self.batch_walls)
            lats = list(self.request_latencies)
            waits = list(self.request_waits)
            fulls = [float(v) for v in self.request_full_steps]
            spread = list(self.batch_lane_spread)
            buckets = list(self.batch_buckets)
            occ = list(self.batch_occupancy)
            depths = list(self.queue_depths)
            ttfr = self.time_to_first_result_s
            state_bytes = self.cache_state_bytes_per_lane
            hits, misses = self.compile_hits, self.compile_misses
            frac = self.full_steps / max(self.total_steps, 1)
            signatures = self.compiled_signatures
            errors = list(self.request_realized_errors)
            budget_events = self.budget_events_total
            shed = self.shed_events
            stale_kills = self.stale_pong_kills
            per_group = {
                k: {"batches": g[0], "requests": g[1],
                    "mean_occupancy": round(g[2] / max(g[0], 1), 3),
                    "budget_events": g[3],
                    "realized_error_p95": (round(percentile(g[4], 95), 6)
                                           if g[4] else None)}
                for k, g in self.group_batches.items()}
            per_shape = {
                k: {"batches": s[0], "requests": s[1],
                    "mean_occupancy": round(s[2] / max(s[0], 1), 3),
                    "state_bytes_per_lane":
                        self.state_bytes_by_shape.get(k)}
                for k, s in self.shape_batches.items()}
        return {
            "requests": len(lats),
            "batches": len(walls),
            "mean_occupancy": round(sum(occ) / max(len(walls), 1), 3),
            "mean_bucket": round(sum(buckets) / max(len(walls), 1), 2),
            "batch_wall_p50_s": round(percentile(walls, 50), 4),
            "batch_wall_p95_s": round(percentile(walls, 95), 4),
            "request_latency_p50_s": round(percentile(lats, 50), 4),
            "request_latency_p95_s": round(percentile(lats, 95), 4),
            "request_wait_p50_s": round(percentile(waits, 50), 4),
            "full_step_fraction": round(frac, 4),
            "skip_compute_fraction": round(1.0 - frac, 4),
            "request_full_p50": percentile(fulls, 50),
            # None (not 0.0) when no request carried a quality SLO
            "realized_error_p50": (round(percentile(errors, 50), 6)
                                   if errors else None),
            "realized_error_p95": (round(percentile(errors, 95), 6)
                                   if errors else None),
            "budget_events": budget_events,
            "shed_events": shed,
            "stale_pong_kills": stale_kills,
            "max_lane_full_spread": max(spread, default=0),
            "compile_hits": hits,
            "compile_misses": misses,
            "compiled_signatures": signatures,
            "policy_groups": len(per_group),
            "per_group": per_group,
            "shape_keys": len(per_shape),
            "per_shape": per_shape,
            "max_queue_depth": max(depths, default=0),
            "time_to_first_result_s": (None if ttfr is None
                                       else round(ttfr, 4)),
            "cache_state_bytes_per_lane": state_bytes,
        }

    def snapshot(self) -> "ServeMetrics":
        """Copy for before/after deltas (e.g. steady-state recompiles)."""
        with self._lock:
            return dataclasses.replace(
                self,
                batch_walls=list(self.batch_walls),
                batch_buckets=list(self.batch_buckets),
                batch_occupancy=list(self.batch_occupancy),
                batch_lane_spread=list(self.batch_lane_spread),
                request_waits=list(self.request_waits),
                request_latencies=list(self.request_latencies),
                request_full_steps=list(self.request_full_steps),
                request_realized_errors=list(self.request_realized_errors),
                queue_depths=list(self.queue_depths),
                group_batches={k: v[:4] + [list(v[4])]
                               for k, v in self.group_batches.items()},
                shape_batches={k: list(v)
                               for k, v in self.shape_batches.items()},
                state_bytes_by_shape=dict(self.state_bytes_by_shape),
                _lock=_metrics_lock(),
            )

    # --- serialization / fleet merge -------------------------------------
    def to_dict(self) -> Dict:
        """Lossless snapshot as plain python values — the wire format a
        replica worker ships to the fleet router (and the ONE sanctioned
        way to read raw counters from outside: benchmarks and the fleet
        aggregator go through this instead of reaching into fields)."""
        with self._lock:
            d = {f: getattr(self, f) for f in _COUNTER_FIELDS}
            d.update({f: list(getattr(self, f)) for f in _LIST_FIELDS})
            d.update({f: getattr(self, f) for f in _OPTIONAL_FIELDS})
            d["group_batches"] = {k: v[:4] + [list(v[4])]
                                  for k, v in self.group_batches.items()}
            d["shape_batches"] = {k: list(v)
                                  for k, v in self.shape_batches.items()}
            d["state_bytes_by_shape"] = dict(self.state_bytes_by_shape)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "ServeMetrics":
        """Inverse of :meth:`to_dict` (``to_dict . from_dict == id``).

        Missing fields default (0 / [] / None) so snapshots written by
        an older wire schema — a replica one release behind its router
        — still load."""
        m = cls()
        for f in _COUNTER_FIELDS:
            setattr(m, f, int(d.get(f, 0)))
        for f in _LIST_FIELDS:
            setattr(m, f, list(d.get(f, ())))
        for f in _OPTIONAL_FIELDS:
            setattr(m, f, d.get(f))
        m.group_batches = {k: v[:4] + [list(v[4])]
                           for k, v in d.get("group_batches", {}).items()}
        # absent in pre-multires snapshots: default to empty (tolerant)
        m.shape_batches = {k: list(v)
                           for k, v in d.get("shape_batches", {}).items()}
        m.state_bytes_by_shape = dict(d.get("state_bytes_by_shape", {}))
        return m

    @classmethod
    def merge(cls, parts) -> "ServeMetrics":
        """Fold snapshots (``ServeMetrics`` or ``to_dict`` dicts) from
        independent engines into one fleet-wide instance.

        Counters sum, observation lists concatenate (so ``summary()``
        percentiles are exact fleet-wide, not averages of averages),
        ``time_to_first_result_s`` is the fleet minimum,
        ``cache_state_bytes_per_lane`` the maximum (replicas of one
        deployment report the same figure), and ``compiled_signatures``
        the fleet total of present probes.  Associative: merging merges
        gives the same ``summary()`` as merging everything at once.
        """
        merged = cls()
        for part in parts:
            d = part if isinstance(part, dict) else part.to_dict()
            for f in _COUNTER_FIELDS:
                setattr(merged, f, getattr(merged, f) + int(d.get(f, 0)))
            for f in _LIST_FIELDS:
                getattr(merged, f).extend(d.get(f, ()))
            ttfr = d.get("time_to_first_result_s")
            if ttfr is not None:
                cur = merged.time_to_first_result_s
                merged.time_to_first_result_s = (
                    ttfr if cur is None else min(cur, ttfr))
            cache_bytes = d.get("cache_state_bytes_per_lane")
            if cache_bytes is not None:
                cur = merged.cache_state_bytes_per_lane
                merged.cache_state_bytes_per_lane = max(
                    cur if cur is not None else 0, cache_bytes)
            sigs = d.get("compiled_signatures")
            if sigs is not None:
                cur = merged.compiled_signatures
                merged.compiled_signatures = (
                    (cur if cur is not None else 0) + sigs)
            for k, v in d.get("group_batches", {}).items():
                g = merged.group_batches.setdefault(k, [0, 0, 0.0, 0, []])
                g[0] += v[0]
                g[1] += v[1]
                g[2] += v[2]
                g[3] += v[3]
                g[4].extend(v[4])
            for k, v in d.get("shape_batches", {}).items():
                s = merged.shape_batches.setdefault(k, [0, 0, 0.0])
                s[0] += v[0]
                s[1] += v[1]
                s[2] += v[2]
            for k, v in d.get("state_bytes_by_shape", {}).items():
                # replicas of one deployment report the same figure
                merged.state_bytes_by_shape[k] = max(
                    merged.state_bytes_by_shape.get(k, 0), int(v))
        return merged


def throughput(metrics: ServeMetrics, wall_s: float) -> Optional[float]:
    if wall_s <= 0:
        return None
    return metrics.n_requests / wall_s
