"""Serving metrics: queue depth, batch occupancy, latency percentiles,
full-step fraction, and compile-cache accounting.

One ``ServeMetrics`` instance per engine.  Recording is cheap (python
lists + counters); ``summary()`` does the aggregation so it can be
called once at the end of a serving run or periodically for dashboards.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    k = max(0, min(len(ys) - 1, int(round(q / 100.0 * (len(ys) - 1)))))
    return float(ys[k])


@dataclasses.dataclass
class ServeMetrics:
    # compile cache
    compile_hits: int = 0
    compile_misses: int = 0
    # batch-level observations
    batch_walls: List[float] = dataclasses.field(default_factory=list)
    batch_buckets: List[int] = dataclasses.field(default_factory=list)
    batch_occupancy: List[float] = dataclasses.field(default_factory=list)
    full_steps: int = 0
    total_steps: int = 0
    # request-level observations
    request_waits: List[float] = dataclasses.field(default_factory=list)
    request_latencies: List[float] = dataclasses.field(default_factory=list)
    # queue depth samples (taken whenever the engine polls the queue)
    queue_depths: List[int] = dataclasses.field(default_factory=list)

    # --- recording -------------------------------------------------------
    def observe_compile(self, hit: bool) -> None:
        if hit:
            self.compile_hits += 1
        else:
            self.compile_misses += 1

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depths.append(int(depth))

    def observe_batch(self, bucket: int, n_real: int, wall_s: float,
                      n_full: int, n_steps: int) -> None:
        self.batch_walls.append(float(wall_s))
        self.batch_buckets.append(int(bucket))
        self.batch_occupancy.append(n_real / max(bucket, 1))
        # padded lanes still burn the compute, so account per-lane
        self.full_steps += int(n_full) * int(bucket)
        self.total_steps += int(n_steps) * int(bucket)

    def observe_request(self, wait_s: float, latency_s: float) -> None:
        self.request_waits.append(float(wait_s))
        self.request_latencies.append(float(latency_s))

    # --- aggregation -----------------------------------------------------
    @property
    def n_requests(self) -> int:
        return len(self.request_latencies)

    @property
    def n_batches(self) -> int:
        return len(self.batch_walls)

    def full_step_fraction(self) -> float:
        return self.full_steps / max(self.total_steps, 1)

    def summary(self) -> Dict:
        walls = self.batch_walls
        lats = self.request_latencies
        return {
            "requests": self.n_requests,
            "batches": self.n_batches,
            "mean_occupancy": round(
                sum(self.batch_occupancy) / max(self.n_batches, 1), 3),
            "mean_bucket": round(
                sum(self.batch_buckets) / max(self.n_batches, 1), 2),
            "batch_wall_p50_s": round(percentile(walls, 50), 4),
            "batch_wall_p95_s": round(percentile(walls, 95), 4),
            "request_latency_p50_s": round(percentile(lats, 50), 4),
            "request_latency_p95_s": round(percentile(lats, 95), 4),
            "request_wait_p50_s": round(
                percentile(self.request_waits, 50), 4),
            "full_step_fraction": round(self.full_step_fraction(), 4),
            "compile_hits": self.compile_hits,
            "compile_misses": self.compile_misses,
            "max_queue_depth": max(self.queue_depths, default=0),
        }

    def snapshot(self) -> "ServeMetrics":
        """Copy for before/after deltas (e.g. steady-state recompiles)."""
        return dataclasses.replace(
            self,
            batch_walls=list(self.batch_walls),
            batch_buckets=list(self.batch_buckets),
            batch_occupancy=list(self.batch_occupancy),
            request_waits=list(self.request_waits),
            request_latencies=list(self.request_latencies),
            queue_depths=list(self.queue_depths),
        )


def throughput(metrics: ServeMetrics, wall_s: float) -> Optional[float]:
    if wall_s <= 0:
        return None
    return metrics.n_requests / wall_s
