"""Batched serving engines.

``DiffusionEngine`` — the paper's deployment shape: requests queue up,
the batcher pads them to a fixed batch signature, and one jitted
FreqCa-cached sampler serves the whole batch.  Jit cache is keyed on
(batch, steps, policy) so steady-state serving never recompiles.

``LMEngine`` — prefill + decode for the assigned LM architectures
(KV-cache ring for sliding-window configs).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cache import CachePolicy
from repro.diffusion import sampler as sampler_lib
from repro.diffusion import schedule
from repro.models import blocks, transformer


@dataclasses.dataclass
class DiffusionRequest:
    request_id: int
    seed: int
    # optional conditioning (e.g. reference latents for editing)
    init_latents: Optional[jnp.ndarray] = None
    edit_strength: float = 0.0


class DiffusionResult(NamedTuple):
    request_id: int
    latents: jnp.ndarray
    n_full_steps: int
    wall_time_s: float


class DiffusionEngine:
    """Queue + fixed-batch FreqCa-cached rectified-flow sampler."""

    def __init__(self, full_fn: Callable, from_crf_fn: Callable,
                 latent_shape, crf_shape, policy: CachePolicy,
                 n_steps: int = 50, max_batch: int = 8,
                 crf_dtype=jnp.float32):
        self.full_fn = full_fn
        self.from_crf_fn = from_crf_fn
        self.latent_shape = tuple(latent_shape)      # [H, W, C]
        self.crf_shape = tuple(crf_shape)            # per-sample CRF [S, D]
        self.policy = policy
        self.n_steps = n_steps
        self.max_batch = max_batch
        self.crf_dtype = crf_dtype
        self.queue: List[DiffusionRequest] = []

    def submit(self, req: DiffusionRequest) -> None:
        self.queue.append(req)

    @functools.lru_cache(maxsize=8)
    def _compiled(self, batch: int):
        ts = schedule.timesteps(self.n_steps)

        def run(x_init):
            res = sampler_lib.sample(
                self.full_fn, self.from_crf_fn, x_init, ts, self.policy,
                crf_shape=(batch,) + self.crf_shape,
                crf_dtype=self.crf_dtype)
            return res.x, res.n_full
        return jax.jit(run)

    def run_batch(self) -> List[DiffusionResult]:
        if not self.queue:
            return []
        reqs, self.queue = self.queue[:self.max_batch], \
            self.queue[self.max_batch:]
        batch = len(reqs)
        pad = self.max_batch - batch           # fixed signature: pad to max
        noises = [jax.random.normal(jax.random.key(r.seed),
                                    self.latent_shape) for r in reqs]
        noises += [jnp.zeros(self.latent_shape)] * pad
        x_init = jnp.stack(noises)
        for i, r in enumerate(reqs):
            if r.init_latents is not None:
                # image editing: start from a partially noised reference
                t0 = r.edit_strength
                x_init = x_init.at[i].set(
                    schedule.add_noise(r.init_latents, x_init[i], t0))
        t0 = time.perf_counter()
        x, n_full = self._compiled(self.max_batch)(x_init)
        x.block_until_ready()
        dt = time.perf_counter() - t0
        return [DiffusionResult(r.request_id, x[i], int(n_full), dt)
                for i, r in enumerate(reqs)]


class LMEngine:
    """Prefill + greedy decode for assigned LM architectures."""

    def __init__(self, params, cfg: ModelConfig, max_len: int,
                 window: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.window = window or cfg.sliding_window
        cache_len = self.window if self.window > 0 else max_len

        def prefill(params, tokens, cache):
            # teacher-forced prefill via repeated decode is wasteful; use
            # full forward for logits, then replay tokens into the cache.
            out = transformer.forward(params, tokens, cfg, remat=False)
            return out.logits

        def decode(params, tok, cache):
            return transformer.decode_step(params, tok, cache, cfg,
                                           window=self.window)

        self._decode = jax.jit(decode)
        self._cache_len = cache_len

    def new_cache(self, batch: int):
        return blocks.stack_cache_zeros(self.cfg, batch, self._cache_len,
                                        jnp.dtype(self.cfg.dtype))

    def generate(self, prompt_tokens: jnp.ndarray, n_new: int):
        """prompt_tokens: [B, P] -> [B, P + n_new] greedy continuation."""
        b, p = prompt_tokens.shape
        cache = self.new_cache(b)
        logits = None
        for i in range(p):   # replayed prefill (decode-path reference)
            logits, cache = self._decode(self.params,
                                         prompt_tokens[:, i:i + 1], cache)
        toks = [prompt_tokens]
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(n_new):
            toks.append(cur)
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jnp.concatenate(toks, axis=1)
