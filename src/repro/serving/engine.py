"""Batched serving engines.

``DiffusionEngine`` — continuous-batching deployment of the FreqCa
sampler: requests land in a ``Scheduler`` queue, batches are cut on
age/deadline pressure and quantised to power-of-two *bucket signatures*
(see repro.serving.scheduler), and one jitted sampler executable per
(bucket, lane-policy) signature serves them for the life of the
process.  Requests may carry their own cache policy: lanes are driven
through a per-lane policy bank (repro.core.policies), every request
gets its own activation schedule and per-request ``n_full_steps``
accounting, and a uniform batch collapses to the single-policy
signature so the default ladder is exactly one executable per bucket —
zero steady-state recompiles once a signature is warm.  By default
(``group_policies=True``) the scheduler cuts **policy-homogeneous**
batches — one compatibility group per cut — so mixed streams compile
O(groups x buckets) signatures (warm them with
``warmup(policies=[...])``, one ladder per group) and static-schedule
lanes never pay for adaptive lanes' activations;
``group_policies=False`` keeps the ungrouped mixed-lane former (one
signature per lane-policy mix, the pre-grouping baseline).  The input
buffer is donated (``donate_argnums=0``) so the noise batch is reused
as sampler scratch.  When a ``jax.sharding.Mesh`` is supplied the batch
is placed via ``repro.sharding.partitioning.batch_spec`` so GSPMD
splits lanes over the data axes.

The execution path (``execute_plan``) is shared with
``repro.serving.async_engine.AsyncDiffusionEngine``, which adds a
thread-safe submit-returns-future path and a background worker.

``LMEngine`` — prefill + decode for the assigned LM architectures
(KV-cache ring for sliding-window configs); the prompt is prefilled in
one jitted dispatch (a ``lax.scan`` of the decode path), not one
dispatch per prompt token.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import runtime as sanitize
from repro.configs.base import ModelConfig
from repro.diffusion import sampler as sampler_lib
from repro.diffusion import schedule
from repro.models import blocks, transformer
from repro.serving.metrics import ServeMetrics
from repro.serving.scheduler import (BatchPlan, DiffusionRequest, Scheduler,
                                     bucket_sizes)

__all__ = ["DiffusionEngine", "DiffusionRequest", "DiffusionResult",
           "LMEngine"]


class DiffusionResult(NamedTuple):
    request_id: int
    latents: jnp.ndarray
    n_full_steps: int        # THIS request's activated steps (per lane)
    wall_time_s: float
    queue_wait_s: float = 0.0
    bucket: int = 0
    # quality SLO report (error-feedback policies only): peak cache
    # error accumulated between full forwards, and how many fulls the
    # budget triggered for this request's lane
    realized_error: Optional[float] = None
    budget_events: Optional[int] = None


class DiffusionEngine:
    """Continuous-batching FreqCa-cached rectified-flow sampler."""

    def __init__(self, full_fn: Callable, from_crf_fn: Callable,
                 latent_shape, crf_shape, policy,
                 n_steps: int = 50, max_batch: int = 8,
                 crf_dtype=jnp.float32, max_wait_s: float = 0.0,
                 pad_to_max: bool = False, mesh=None,
                 group_policies: bool = True,
                 shed_depth: Optional[int] = None,
                 shed_factor: float = 4.0,
                 shapes: Sequence = ()):
        self.full_fn = full_fn
        self.from_crf_fn = from_crf_fn
        self.latent_shape = tuple(latent_shape)      # [H, W, C]
        self.crf_shape = tuple(crf_shape)            # per-sample CRF [S, D]
        self.policy = policy
        self.n_steps = n_steps
        self.max_batch = max_batch
        self.crf_dtype = crf_dtype
        self.mesh = mesh
        self.group_policies = group_policies
        # multi-resolution shape ladder: (latent_shape, crf_shape)
        # pairs this deployment serves.  The default is always first;
        # ``shapes`` adds more at construction, ``warmup(shapes=[...])``
        # at warmup.  ``_allowed_shapes`` is shared by reference with
        # the scheduler, so submit-time validation tracks declarations.
        self.default_shape = (self.latent_shape, self.crf_shape)
        self.shapes: List = [self.default_shape]
        self._allowed_shapes = {self.default_shape}
        for pair in shapes:
            self.declare_shape(*pair)
        self.scheduler = Scheduler(max_batch=max_batch,
                                   max_wait_s=max_wait_s,
                                   pad_to_max=pad_to_max,
                                   group_policies=group_policies,
                                   default_policy=policy,
                                   shed_depth=shed_depth,
                                   shed_factor=shed_factor,
                                   default_shape=self.default_shape,
                                   allowed_shapes=self._allowed_shapes)
        self.metrics = ServeMetrics()
        self._ts = schedule.timesteps(n_steps)

        def run(x_init, lane_policies, crf_feat):
            # batch size, the per-lane policy signature, and the
            # per-sample CRF shape are static at trace time -> one
            # executable per (shape, group, bucket) triple, cached for
            # the process lifetime
            batch = x_init.shape[0]
            res = sampler_lib.sample(
                self.full_fn, self.from_crf_fn, x_init, self._ts,
                lane_policies, crf_shape=(batch,) + tuple(crf_feat),
                crf_dtype=self.crf_dtype)
            # feedback is None (an empty pytree) unless some lane's
            # policy consumes error observations, so non-SLO signatures
            # stay byte-identical programs
            return res.x, res.n_full, res.n_full_lanes, res.feedback

        self._jit_run = jax.jit(run, static_argnums=(1, 2),
                                donate_argnums=0)

    def declare_shape(self, latent_shape, crf_shape) -> tuple:
        """Add a (latent, CRF) shape pair to the deployment's ladder so
        submits carrying it validate; warm it (``warmup``) before
        steady-state traffic to keep serving compile-free."""
        key = (tuple(latent_shape), tuple(crf_shape))
        if key not in self._allowed_shapes:
            self.shapes.append(key)
            self._allowed_shapes.add(key)
        return key

    @staticmethod
    def _shape_label(latent_shape, crf_shape) -> str:
        """Compact per-shape metrics key, e.g. ``lat32x32x4/crf256x128``."""
        return ("lat" + "x".join(str(d) for d in latent_shape)
                + "/crf" + "x".join(str(d) for d in crf_shape))

    @staticmethod
    def _normalize_signature(lanes):
        """Collapse an all-equal lane assignment to the single policy so
        uniform batches of any composition share the per-bucket ladder."""
        lanes = tuple(lanes)
        if all(p == lanes[0] for p in lanes):
            return lanes[0]
        return lanes

    def state_bytes(self, batch: int = 1, latent_shape=None,
                    crf_shape=None) -> int:
        """Real cache-state footprint of the engine policy for a
        ``batch``-lane bucket — the number Table-5/``ServeMetrics``
        report.  With the spectral FreqCa cache the low ring holds
        ``m = kept_bins(S, rho)`` coefficient rows instead of S spatial
        rows, so this is ~``rho`` of the spatial figure for the low
        band.  ``latent_shape``/``crf_shape`` select a ladder entry
        (default: the engine's primary shape) — the per-S spectral
        state means each shape has its own footprint."""
        from repro.core.policies import registry as policy_registry
        pol = policy_registry.resolve(self.policy)
        lat = tuple(latent_shape) if latent_shape else self.latent_shape
        crf = tuple(crf_shape) if crf_shape else self.crf_shape
        state = jax.eval_shape(
            lambda: pol.init(batch, crf, self.crf_dtype,
                             latent_shape=lat,
                             latent_dtype=jnp.float32))
        # the policy's own accounting hook (works on the eval_shape
        # pytree: ShapeDtypeStruct carries .size and .dtype)
        return pol.state_bytes(state)

    # --- compile-cache management ---------------------------------------
    @property
    def buckets(self) -> List[int]:
        return bucket_sizes(self.max_batch)

    def metrics_dict(self) -> Dict:
        """Lossless ``ServeMetrics`` snapshot (plain python values, safe
        to ship across a process boundary) — the fleet-export hook a
        replica worker answers ``("metrics",)`` with."""
        return self.metrics.to_dict()

    def compiled_buckets(self) -> int:
        """Jit-cache probe: number of bucket executables compiled so far."""
        try:
            return self._jit_run._cache_size()
        except AttributeError:
            # private jax API; if it moves, serving must keep working —
            # compile accounting degrades to all-hits
            return -1

    def signature_budget(self, n_groups: int = 1) -> int:
        """Upper bound on compiled signatures for steady-state traffic:
        ``shapes x groups x buckets`` (the multi-resolution invariant
        the bench guard asserts)."""
        return len(self.shapes) * max(n_groups, 1) * len(self.buckets)

    def warmup(self, buckets: Optional[Sequence[int]] = None,
               lane_policy_sets: Sequence[Sequence[object]] = (),
               policies: Sequence[object] = (),
               shapes: Sequence = ()) -> float:
        """Precompile sampler executables for every bucket signature on
        the default policy, plus any extra per-lane policy signatures
        (``lane_policy_sets``: each entry is a full per-lane assignment
        whose length must be a bucket size), plus a full per-bucket
        ladder for every extra uniform policy in ``policies`` — the
        grouped-serving warmup: a policy-homogeneous batch former cuts
        uniform signatures whenever a group is a single policy value,
        so one ladder per policy value covers the whole stream
        (O(groups x buckets) executables instead of one per lane-policy
        mix).  Static families that mix distinct member values in one
        cut (``fora(interval=1)`` + ``none``) compile one extra
        signature per policy *composition* on first use — the scheduler
        canonicalizes lane order so interleavings collapse — cached for
        the process lifetime; pre-warm those with ``lane_policy_sets``.

        ``shapes`` — extra (latent_shape, crf_shape) pairs to declare
        (they join the ladder, so submits carrying them validate) and
        warm.  Every warmed (bucket, policy-signature) pair is compiled
        once per declared shape: the multi-resolution executable count
        is exactly ``shapes x groups x buckets``
        (``signature_budget``), and a mixed-resolution stream then
        serves with zero steady-state recompiles.

        Returns wall seconds spent.  After warmup, serving any mix of
        batch sizes — and any warmed policy mix, at any declared shape
        — hits the jit cache: zero steady-state recompiles.
        """
        t0 = time.perf_counter()
        for pair in shapes:
            self.declare_shape(*pair)
        for lat, crf in self.shapes:
            self.metrics.observe_state_bytes(
                self.state_bytes(batch=1, latent_shape=lat, crf_shape=crf),
                shape_key=self._shape_label(lat, crf))
        sigs = [(b, self.policy) for b in (buckets or self.buckets)]
        for pol in policies:
            sigs.extend((b, pol) for b in self.buckets
                        if pol != self.policy)
        for lanes in lane_policy_sets:
            lanes = tuple(lanes)
            if len(lanes) not in self.buckets:
                raise ValueError(f"lane policy set of length {len(lanes)} "
                                 f"matches no bucket in {self.buckets}")
            sigs.append((len(lanes), self._normalize_signature(lanes)))
        for lat, crf in self.shapes:
            for b, sig in sigs:
                x = self._place(jnp.zeros((b,) + lat))
                cache_before = self.compiled_buckets()
                out = self._jit_run(x, sig, crf)[0]
                out.block_until_ready()
                self.metrics.observe_compile(
                    hit=self.compiled_buckets() == cache_before)
        self.metrics.observe_compiled_signatures(self.compiled_buckets())
        return time.perf_counter() - t0

    # --- request path ----------------------------------------------------
    def submit(self, req: DiffusionRequest,
               now: Optional[float] = None) -> None:
        self.scheduler.submit(req, now=now)

    def build_x_init(self, plan: BatchPlan) -> jnp.ndarray:
        """[bucket, H, W, C] noise batch at the plan's latent shape;
        editing lanes partially noised, padded lanes zero.  Cuts are
        shape-pure, so one shape covers every lane."""
        lat = (tuple(plan.latent_shape) if plan.latent_shape is not None
               else self.latent_shape)
        lanes = []
        for r in plan.requests:
            noise = jax.random.normal(jax.random.key(r.seed), lat)
            if r.init_latents is not None:
                # image editing: start from a partially noised reference
                ref = jnp.asarray(r.init_latents, noise.dtype)
                lanes.append(schedule.add_noise(ref, noise,
                                                r.edit_strength))
            else:
                lanes.append(noise)
        lanes += [jnp.zeros(lat)] * (plan.bucket - plan.n_real)
        return jnp.stack(lanes)

    def _place(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.mesh is None:
            return jax.device_put(x)
        from repro.sharding import partitioning
        return jax.device_put(
            x, partitioning.batch_spec(self.mesh, x.shape[0], x.ndim))

    def execute_plan(self, plan: BatchPlan) -> List[DiffusionResult]:
        """Run one formed batch through the jitted sampler and build the
        per-request results.  This is the single execution path shared by
        the sync drivers (``run_batch``) and ``AsyncDiffusionEngine``'s
        worker thread — only one thread may call it at a time (the async
        engine guarantees this by owning a single worker)."""
        x_init = self._place(self.build_x_init(plan))
        sig = self._normalize_signature(plan.lane_policies(self.policy))
        crf = (tuple(plan.crf_shape) if plan.crf_shape is not None
               else self.crf_shape)
        lat = (tuple(plan.latent_shape) if plan.latent_shape is not None
               else self.latent_shape)
        if sanitize.enabled():
            # a tracer stashed on a policy object would poison the jit
            # cache key (new signature every batch -> recompiles) or
            # crash later with a leaked-tracer error far from the cause
            sanitize.check_tracer_leaks(sig, "policy signature")
        cache_before = self.compiled_buckets()
        t0 = time.perf_counter()
        x, n_forwards, lane_full, feedback = self._jit_run(x_init, sig, crf)
        x.block_until_ready()
        wall = time.perf_counter() - t0
        lane_err = lane_ev = None
        if feedback is not None:
            lane_err = [float(v) for v in feedback.realized[:plan.n_real]]
            lane_ev = [int(v) for v in feedback.events[:plan.n_real]]
        self.metrics.observe_compile(
            hit=self.compiled_buckets() == cache_before)
        self.metrics.observe_compiled_signatures(self.compiled_buckets())
        self.metrics.observe_batch(
            plan.bucket, plan.n_real, wall, int(n_forwards), self.n_steps,
            lane_full=[int(v) for v in lane_full[:plan.n_real]],
            group_key=plan.group_key,
            lane_errors=lane_err, lane_events=lane_ev,
            shape_key=self._shape_label(lat, crf))
        self.metrics.observe_shed_events(self.scheduler.shed_events)
        out = []
        for i, r in enumerate(plan.requests):   # padded lanes never leak
            err = lane_err[i] if lane_err is not None else None
            ev = lane_ev[i] if lane_ev is not None else None
            wait = max(0.0, plan.formed_at - r.submit_time)
            self.metrics.observe_request(wait, wait + wall,
                                         n_full=int(lane_full[i]),
                                         realized_error=err,
                                         budget_events=ev)
            out.append(DiffusionResult(r.request_id, x[i],
                                       int(lane_full[i]), wall, wait,
                                       plan.bucket,
                                       realized_error=err,
                                       budget_events=ev))
        return out

    # backwards-compatible alias (pre-async name)
    _execute = execute_plan

    def run_batch(self, reqs: Optional[Sequence[DiffusionRequest]] = None,
                  flush: bool = True,
                  now: Optional[float] = None) -> List[DiffusionResult]:
        """Cut and serve one batch.  ``flush=True`` (default) drains the
        queue immediately; ``flush=False`` respects age/deadline-based
        batch formation and returns [] while the scheduler holds back.

        ``reqs`` — optional :class:`DiffusionRequest` objects to submit
        first: the one-shot sync entry point, taking exactly the request
        type (and field semantics) the async engine's ``submit`` does.
        """
        for r in (reqs or ()):
            self.submit(r, now=now)
        self.metrics.observe_queue_depth(self.scheduler.depth)
        plan = self.scheduler.form_batch(now=now, flush=flush)
        if plan is None:
            return []
        return self.execute_plan(plan)

    def serve_until_drained(self, flush: bool = True,
                            poll_s: float = 0.005) -> List[DiffusionResult]:
        out: List[DiffusionResult] = []
        while self.scheduler.depth:
            served = self.run_batch(flush=flush)
            out.extend(served)
            if not served:   # scheduler holding back: wait, don't spin
                time.sleep(poll_s)
        return out


class LMEngine:
    """Prefill + greedy decode for assigned LM architectures."""

    def __init__(self, params, cfg: ModelConfig, max_len: int,
                 window: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.window = window or cfg.sliding_window
        cache_len = self.window if self.window > 0 else max_len

        def prefill(params, tokens, cache):
            # single jitted dispatch for the whole prompt: scan the
            # decode path over the prompt positions so the KV/SSM cache
            # fills, carrying only the last position's logits.  One
            # executable per prompt length (the scan length is static).
            def step(carry, tok):
                c, prev = carry
                logits, c = transformer.decode_step(params, tok[:, None],
                                                    c, cfg,
                                                    window=self.window)
                return (c, logits.astype(prev.dtype)), None

            init = (cache, jnp.zeros((tokens.shape[0], 1, cfg.vocab_size),
                                     jnp.dtype(cfg.dtype)))
            (cache, logits), _ = jax.lax.scan(
                step, init, jnp.moveaxis(tokens, 1, 0))
            return logits, cache

        def decode(params, tok, cache):
            return transformer.decode_step(params, tok, cache, cfg,
                                           window=self.window)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)
        self._cache_len = cache_len

    def new_cache(self, batch: int):
        return blocks.stack_cache_zeros(self.cfg, batch, self._cache_len,
                                        jnp.dtype(self.cfg.dtype))

    def generate(self, prompt_tokens: jnp.ndarray, n_new: int):
        """prompt_tokens: [B, P] -> [B, P + n_new] greedy continuation.

        The prompt is prefetched in ONE jitted dispatch (``_prefill``
        scans the decode path over the P positions and fills the cache),
        not P per-token dispatches; decode then proceeds one token at a
        time.
        """
        logits, cache = self._prefill(self.params,
                                      prompt_tokens.astype(jnp.int32),
                                      self.new_cache(prompt_tokens.shape[0]))
        toks = [prompt_tokens]
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(n_new):
            toks.append(cur)
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return jnp.concatenate(toks, axis=1)
