"""repro.analysis — repo-aware invariant linter + runtime sanitizers.

The serving stack's headline guarantees (zero steady-state recompiles,
bitwise policy equivalence, exactly-once future resolution across the
fleet) are invariants that every PR touches but no single test owns.
This package turns them into mechanical checks:

* **Static linter** (``python -m repro.analysis``, AST-based, stdlib
  only — zero runtime deps): recompile hazards (import-frozen
  ``os.environ`` reads, unhashable static jit args, python control flow
  on traced values in policy methods), lock discipline (the
  lock-acquisition graph across the serving stack must stay acyclic;
  ``Future.set_result``/``set_exception`` must use the exactly-once
  guard), and donated-buffer reuse after a donating jit call.
  Findings are suppressible with ``# repro: allow[rule]: why`` comments
  (the justification is mandatory).

* **Runtime sanitizers** (``repro.analysis.runtime``, opt-in via
  ``REPRO_SANITIZE=1``): an instrumented lock wrapper that records the
  fleet-wide lock-order graph and fails fast on a would-be inversion,
  and a tracer-leak check for policy pytrees that the engine runs after
  every jitted dispatch.

Import cost matters: ``repro.serving`` imports :mod:`.runtime` on every
engine construction, so this ``__init__`` stays empty and the linter
modules (which pull in :mod:`ast`) load only when the CLI runs.
"""
from __future__ import annotations

__all__ = ["analyze_paths", "Finding"]


def __getattr__(name: str):
    # lazy: the serving stack imports repro.analysis.runtime; don't make
    # it pay for the linter's ast machinery
    if name in __all__:
        from repro.analysis import core
        return getattr(core, name)
    raise AttributeError(name)
