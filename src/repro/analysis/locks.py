"""Lock-discipline rules.

``lock-order``
    The serving stack's threads (async-engine worker, fleet receiver /
    monitor threads, client submitters) share a handful of class-level
    locks: ``Scheduler.cv``, ``ServeMetrics._lock``,
    ``FleetRouter._lock``, the per-replica send locks.  A deadlock
    needs two threads acquiring two of them in opposite orders, so the
    invariant is: the *static* lock-acquisition graph (edge ``H -> N``
    whenever ``N`` can be acquired while ``H`` is held, including
    through calls) stays acyclic.  This pass rebuilds that graph from
    the AST with light repo-aware type inference — constructor
    assignments (``self.scheduler = Scheduler(...)``), parameter
    annotations (``engine: DiffusionEngine``), and attribute
    propagation (``self.metrics = engine.metrics``) — and reports any
    directed cycle.  ``Condition(self._lock)`` aliases to the
    underlying lock's node; re-acquiring the same node is ignored
    (RLock reentrancy / Condition methods).

``future-guard``
    ``Future.set_result`` / ``set_exception`` resolve a future exactly
    once; a second call raises ``InvalidStateError`` *in the worker
    thread*, killing it silently.  The fleet makes double resolution a
    real event (a replica dies after sending a result whose request
    was already requeued), so the router's ``_finish`` absorbs it with
    ``try/except InvalidStateError`` and counts ``duplicate_results``.
    This rule flags any ``set_result``/``set_exception`` call not
    lexically inside that pattern or an ``if ... fut.done() ...`` /
    ``set_running_or_notify_cancel`` guard.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, Project
from repro.analysis.graphs import find_cycle

__all__ = ["run"]

_LOCK_CTORS = {"Lock", "RLock", "make_lock", "make_rlock"}
_COND_CTORS = {"Condition", "make_condition"}


def run(project: Project, findings: List[Finding]) -> None:
    classes = _collect_classes(project)
    _propagate_attr_types(classes)
    _lock_order(project, classes, findings)
    _future_guard(project, findings)


# --- class model ---------------------------------------------------------

class _ClassInfo:
    def __init__(self, name: str, node: ast.ClassDef, mod: Module):
        self.name = name
        self.node = node
        self.mod = mod
        self.lock_attrs: Dict[str, str] = {}   # attr -> graph node name
        self.attr_types: Dict[str, str] = {}   # attr -> class name
        # attr -> element class for List[T]/Dict[_, T]-annotated attrs
        # (so `for r in self.replicas:` types r as Replica)
        self.attr_elem: Dict[str, str] = {}
        # attr -> (param, sub-attr) pending annotation-based resolution
        self.attr_from: Dict[str, Tuple[str, Optional[str]]] = {}
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.param_ann: Dict[str, Dict[str, str]] = {}  # method -> {p: T}


def _ctor_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _ann_name(ann: Optional[ast.AST]) -> Optional[str]:
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split(".")[-1].strip("\"' ")
    if isinstance(ann, ast.Subscript):      # Optional[T] / List[T]
        return _ann_name(ann.slice)
    return None


def _collect_classes(project: Project) -> Dict[str, _ClassInfo]:
    classes: Dict[str, _ClassInfo] = {}
    for mod in project.modules:
        if mod.tree is None:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = classes.setdefault(
                node.name, _ClassInfo(node.name, node, mod))
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    info.methods[item.name] = item
                    anns: Dict[str, str] = {}
                    for a in (item.args.posonlyargs + item.args.args
                              + item.args.kwonlyargs):
                        t = _ann_name(a.annotation)
                        if t:
                            anns[a.arg] = t
                    info.param_ann[item.name] = anns
                # dataclass-style lock field:
                #   _lock: threading.Lock = field(default_factory=...)
                if isinstance(item, ast.AnnAssign) and \
                        isinstance(item.target, ast.Name):
                    t = _ann_name(item.annotation)
                    if t in ("Lock", "RLock"):
                        info.lock_attrs[item.target.id] = \
                            f"{node.name}.{item.target.id}"
            _collect_self_assigns(info)
    return classes


def _collect_self_assigns(info: _ClassInfo) -> None:
    plain: List[Tuple[str, ast.Call]] = []
    conds: List[Tuple[str, ast.Call]] = []
    for fn in info.methods.values():
        for stmt in ast.walk(fn):
            # self.replicas: List[Replica] = [] — remember the element
            # type so loop variables over the container resolve
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Attribute) and \
                    isinstance(stmt.target.value, ast.Name) and \
                    stmt.target.value.id == "self":
                if isinstance(stmt.annotation, ast.Subscript):
                    t = _ann_name(stmt.annotation)
                    if t:
                        info.attr_elem.setdefault(stmt.target.attr, t)
                else:
                    t = _ann_name(stmt.annotation)
                    if t:
                        info.attr_types.setdefault(stmt.target.attr, t)
                continue
            if not (isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1):
                continue
            tgt = stmt.targets[0]
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            val = stmt.value
            if isinstance(val, ast.Call):
                ctor = _ctor_name(val.func)
                if ctor in _LOCK_CTORS:
                    plain.append((tgt.attr, val))
                    continue
                if ctor in _COND_CTORS:
                    conds.append((tgt.attr, val))
                    continue
            _record_attr_source(info, tgt.attr, val)
    for attr, _call in plain:
        info.lock_attrs[attr] = f"{info.name}.{attr}"
    for attr, call in conds:
        # Condition(self.X) / make_condition(name, lock=self.X) share
        # X's node; a Condition over its own (R)Lock gets its own
        node = f"{info.name}.{attr}"
        inner = None
        for cand in list(call.args[:2]) + [
                kw.value for kw in call.keywords if kw.arg == "lock"]:
            if isinstance(cand, ast.Attribute) and \
                    isinstance(cand.value, ast.Name) and \
                    cand.value.id == "self" and \
                    cand.attr in info.lock_attrs:
                inner = info.lock_attrs[cand.attr]
        info.lock_attrs[attr] = inner or node


def _record_attr_source(info: _ClassInfo, attr: str,
                        val: ast.AST) -> None:
    # self.X = ClassName(...)  -> type known immediately (validated
    # against the project class table during propagation)
    if isinstance(val, ast.Call):
        ctor = _ctor_name(val.func)
        if ctor:
            info.attr_types.setdefault(attr, ctor)
        return
    # self.X = param  /  self.X = param.attr  -> resolve via annotation
    if isinstance(val, ast.Name):
        info.attr_from.setdefault(attr, (val.id, None))
    elif isinstance(val, ast.Attribute) and \
            isinstance(val.value, ast.Name):
        info.attr_from.setdefault(attr, (val.value.id, val.attr))


def _propagate_attr_types(classes: Dict[str, _ClassInfo]) -> None:
    # drop ctor "types" that aren't project classes (e.g. dict(), Event())
    for info in classes.values():
        info.attr_types = {a: t for a, t in info.attr_types.items()
                           if t in classes}
        info.attr_elem = {a: t for a, t in info.attr_elem.items()
                          if t in classes}
    changed = True
    while changed:
        changed = False
        for info in classes.values():
            for attr, (param, sub) in info.attr_from.items():
                if attr in info.attr_types or attr in info.lock_attrs:
                    continue
                anns = info.param_ann.get("__init__", {})
                ptype = anns.get(param)
                if ptype is None or ptype not in classes:
                    continue
                if sub is None:
                    info.attr_types[attr] = ptype
                    changed = True
                else:
                    src = classes[ptype]
                    if sub in src.lock_attrs:
                        info.lock_attrs[attr] = src.lock_attrs[sub]
                        changed = True
                    elif sub in src.attr_types:
                        info.attr_types[attr] = src.attr_types[sub]
                        changed = True


# --- lock-order graph ----------------------------------------------------

class _FnScan:
    """One method's acquisitions, edges, and guarded call sites."""

    def __init__(self, cls: _ClassInfo, fn: ast.FunctionDef,
                 classes: Dict[str, _ClassInfo]):
        self.cls = cls
        self.fn = fn
        self.classes = classes
        self.env: Dict[str, str] = dict(
            cls.param_ann.get(fn.name, {}))
        self.env["self"] = cls.name
        self.acquires: Set[str] = set()
        # (held, lock) pairs with a representative source location
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        # calls made while holding >= 1 lock: (callee, held, loc)
        self.calls: List[Tuple[Tuple[str, str], Tuple[str, ...],
                               Tuple[str, int]]] = []
        for stmt in fn.body:
            self._scan(stmt, ())

    # -- resolution -------------------------------------------------------
    def _lock_node(self, expr: ast.AST) -> Optional[str]:
        if not isinstance(expr, ast.Attribute):
            return None
        base_t = self._expr_type(expr.value)
        if base_t is None:
            return None
        info = self.classes.get(base_t)
        if info is None:
            return None
        return info.lock_attrs.get(expr.attr)

    def _expr_type(self, expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Name):
            return self.env.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base_t = self._expr_type(expr.value)
            if base_t and base_t in self.classes:
                return self.classes[base_t].attr_types.get(expr.attr)
        return None

    def _elem_type(self, expr: ast.AST) -> Optional[str]:
        """Element type of a container expression (List[T] attrs)."""
        if isinstance(expr, ast.Attribute):
            base_t = self._expr_type(expr.value)
            if base_t and base_t in self.classes:
                return self.classes[base_t].attr_elem.get(expr.attr)
        return None

    def _callee(self, call: ast.Call) -> Optional[Tuple[str, str]]:
        f = call.func
        if isinstance(f, ast.Attribute):
            base_t = self._expr_type(f.value)
            if base_t and base_t in self.classes and \
                    f.attr in self.classes[base_t].methods:
                return (base_t, f.attr)
        return None

    # -- walk -------------------------------------------------------------
    def _scan(self, node: ast.AST, held: Tuple[str, ...]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return   # nested scope: different env; conservatively skip
        if isinstance(node, ast.With):
            newheld = held
            for item in node.items:
                self._scan(item.context_expr, newheld)
                lock = self._lock_node(item.context_expr)
                if lock is None:
                    continue
                if lock not in newheld:   # reentrant re-acquire is a no-op
                    for h in newheld:
                        self.edges.setdefault(
                            (h, lock),
                            (self.cls.mod.rel, item.context_expr.lineno))
                    self.acquires.add(lock)
                    newheld = newheld + (lock,)
            for stmt in node.body:
                self._scan(stmt, newheld)
            return
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            # track `sched = self.scheduler`-style local aliases
            t = self._expr_type(node.value)
            if t is not None:
                self.env[node.targets[0].id] = t
        if isinstance(node, (ast.For, ast.comprehension)) and \
                isinstance(node.target, ast.Name):
            # `for r in self.replicas:` — element type from List[T]
            elem = self._elem_type(node.iter)
            if elem is not None:
                self.env[node.target.id] = elem
        if isinstance(node, ast.Call):
            callee = self._callee(node)
            if callee is not None:
                self.calls.append(
                    (callee, held,
                     (self.cls.mod.rel, node.lineno)))
            # explicit .acquire() outside a with-statement
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "acquire":
                lock = self._lock_node(node.func.value)
                if lock is not None and lock not in held:
                    for h in held:
                        self.edges.setdefault(
                            (h, lock), (self.cls.mod.rel, node.lineno))
                    self.acquires.add(lock)
        for child in ast.iter_child_nodes(node):
            self._scan(child, held)


def _lock_order(project: Project, classes: Dict[str, _ClassInfo],
                findings: List[Finding]) -> None:
    scans: Dict[Tuple[str, str], _FnScan] = {}
    for info in classes.values():
        for name, fn in info.methods.items():
            scans[(info.name, name)] = _FnScan(info, fn, classes)

    # transitive closure: every lock a method may acquire, through calls
    closure: Dict[Tuple[str, str], Set[str]] = {
        k: set(s.acquires) for k, s in scans.items()}
    changed = True
    while changed:
        changed = False
        for key, scan in scans.items():
            acc = closure[key]
            for callee, _held, _loc in scan.calls:
                extra = closure.get(callee, set()) - acc
                if extra:
                    acc.update(extra)
                    changed = True

    # edge set: direct nesting plus held-across-call acquisitions
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for scan in scans.values():
        for edge, loc in scan.edges.items():
            edges.setdefault(edge, loc)
        for callee, held, loc in scan.calls:
            if not held:
                continue
            for lock in closure.get(callee, ()):
                for h in held:
                    if h != lock:
                        edges.setdefault((h, lock), loc)

    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
    # deterministic order for stable cycle reports
    graph = {a: sorted(bs) for a, bs in sorted(graph.items())}

    cycle = find_cycle(graph)
    while cycle is not None:
        loc = edges.get((cycle[0], cycle[1]))
        path, line = loc if loc else ("<project>", 1)
        findings.append(Finding(
            path, line, "lock-order",
            "lock-acquisition cycle: " + " -> ".join(cycle)
            + " (two threads taking these in opposite orders deadlock)"))
        # remove one edge of the reported cycle and look for more
        graph[cycle[0]] = [b for b in graph[cycle[0]] if b != cycle[1]]
        cycle = find_cycle(graph)


# --- future-guard --------------------------------------------------------

def _catches_invalid_state(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    cands = t.elts if isinstance(t, ast.Tuple) else [t]
    for c in cands:
        if isinstance(c, ast.Name) and c.id in (
                "InvalidStateError", "Exception", "BaseException"):
            return True
        if isinstance(c, ast.Attribute) and c.attr == "InvalidStateError":
            return True
    return False


def _test_is_guard(test: ast.AST) -> bool:
    for sub in ast.walk(test):
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Attribute) and \
                sub.func.attr in ("done", "set_running_or_notify_cancel",
                                  "cancelled"):
            return True
    return False


class _FutureScan(ast.NodeVisitor):
    def __init__(self, mod: Module, findings: List[Finding]):
        self.mod = mod
        self.findings = findings
        self.guard_depth = 0

    def visit_Try(self, node: ast.Try) -> None:
        guarded = any(_catches_invalid_state(h) for h in node.handlers
                      if h.type is not None)
        if guarded:
            self.guard_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if guarded:
            self.guard_depth -= 1
        for part in (node.handlers, node.orelse, node.finalbody):
            for stmt in part:
                self.visit(stmt)

    def visit_If(self, node: ast.If) -> None:
        guarded = _test_is_guard(node.test)
        if guarded:
            self.guard_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if guarded:
            self.guard_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and \
                f.attr in ("set_result", "set_exception") and \
                self.guard_depth == 0:
            self.mod.flag(
                node, "future-guard",
                f"unguarded {f.attr}(): a requeue race can resolve the "
                "future twice and InvalidStateError kills the calling "
                "thread; wrap in try/except InvalidStateError and count "
                "duplicate_results (see FleetRouter._finish) or guard "
                "with `if not fut.done()`",
                self.findings)
        self.generic_visit(node)


def _future_guard(project: Project, findings: List[Finding]) -> None:
    for mod in project.modules:
        if mod.tree is None:
            continue
        _FutureScan(mod, findings).visit(mod.tree)
