"""``donated-reuse``: a donated buffer read after the donating call.

``donate_argnums`` hands the argument's device buffer to XLA for reuse
— the engine's per-bucket executables donate the noise batch
(``donate_argnums=0``) so steady-state serving allocates nothing per
step.  Reading the donated array afterwards raises
``RuntimeError: invalid buffer`` on real backends, but *not* under CPU
interpret mode, so CI's green run doesn't cover it — exactly the kind
of invariant that needs a static check.

The rule: within one function, after a call to a known donating
wrapper (collected by the recompile pass: ``self._jit_run = jax.jit(f,
donate_argnums=0)`` and decorator forms), any ``Name``-load of the
variable that was passed in a donated position is flagged, unless the
name was re-bound first.  Conservative and local by design: aliases
through containers or attributes are out of scope (none exist in the
repo's donating call sites).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.core import Finding, Module, Project
from repro.analysis.recompile import _collect_jit_wrappers, _call_key

__all__ = ["run"]


def run(project: Project, findings: List[Finding]) -> None:
    for mod in project.modules:
        if mod.tree is None:
            continue
        jits = _collect_jit_wrappers(mod)
        donating = {k: v[2] for k, v in jits.items() if v[2]}
        if not donating:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _scan_function(mod, node, donating, findings)


def _scan_function(mod: Module, fn: ast.FunctionDef,
                   donating: Dict[str, Set[int]],
                   findings: List[Finding]) -> None:
    # donated variable name -> (line of the donating call, wrapper key)
    dead: Dict[str, Tuple[int, str]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            key = _call_key(node)
            if key in donating:
                for i in donating[key]:
                    if i < len(node.args) and \
                            isinstance(node.args[i], ast.Name):
                        name = node.args[i].id
                        dead.setdefault(name, (node.lineno, key))
    if not dead:
        return
    # second pass in source order: a store revives the name, a load
    # after the donating call (and before any store) is a bug
    events: List[Tuple[int, int, str, str, ast.AST]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in dead:
            kind = "store" if isinstance(node.ctx, (ast.Store, ast.Del)) \
                else "load"
            events.append((node.lineno, node.col_offset, node.id, kind,
                           node))
    events.sort(key=lambda e: (e[0], e[1]))
    revived: Set[str] = set()
    for lineno, _col, name, kind, node in events:
        call_line, key = dead[name]
        # a store ON the call line is `x = step(x)` — the target binds
        # after the RHS runs, so it revives the name
        if kind == "store" and lineno >= call_line:
            revived.add(name)
            continue
        if lineno <= call_line:
            continue
        if name not in revived:
            mod.flag(
                node, "donated-reuse",
                f"`{name}` was donated to {key}() on line {call_line} "
                "(donate_argnums); its buffer now belongs to XLA and "
                "reading it raises on non-interpret backends — rebind "
                "the name or donate a copy",
                findings)
