"""CLI: ``python -m repro.analysis [--ci] [paths...]``.

Zero runtime deps (stdlib + the repo's own AST passes — jax is never
imported), so the CI job needs no ``pip install`` beyond a checkout.

Exit status: 0 = clean, 1 = findings, 2 = bad invocation.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.core import RULES, analyze_paths, summarize

_CI_PATHS = ("src", "tests", "benchmarks")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware invariant linter for the serving stack "
                    "(recompile hazards, lock discipline, donation)")
    parser.add_argument(
        "paths", nargs="*", type=Path,
        help="files or directories to lint (default: %s)"
             % " ".join(_CI_PATHS))
    parser.add_argument(
        "--ci", action="store_true",
        help="CI mode: default paths to src/ tests/ benchmarks/ and "
             "keep output terse")
    parser.add_argument(
        "--rules", action="store_true",
        help="list the known rule names and exit")
    args = parser.parse_args(argv)

    if args.rules:
        print("\n".join(RULES))
        return 0

    paths = args.paths or [Path(p) for p in _CI_PATHS]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print("no such path: %s" % ", ".join(map(str, missing)),
              file=sys.stderr)
        return 2

    findings = analyze_paths(paths, root=Path.cwd())
    for f in findings:
        print(f)
    if findings:
        print(summarize(findings), file=sys.stderr)
        return 1
    if not args.ci:
        n = len(list(paths))
        print(f"repro.analysis: clean ({n} root(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
