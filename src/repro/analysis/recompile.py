"""Recompile-hazard rules.

``env-read-at-import``
    ``os.environ``/``os.getenv`` *read* at module import time (module
    or class body, outside any function).  Import-frozen env is the
    PR-4 ``INTERPRET`` bug class: the fleet sets per-replica env right
    before the child imports the module, and an import-time read
    freezes the value for the process lifetime.  The sanctioned shape
    is a call-time read (``kernels/ops.py``) or a PEP 562 module
    ``__getattr__``.  Writes (``setdefault``/``update``/``pop``/
    subscript store) are fine, as are reads feeding an ``os.environ``
    write in the same statement (``launch/dryrun.py`` prepends to
    ``XLA_FLAGS``).

``unhashable-static-arg``
    a list/dict/set display (or ``list()``/``dict()``/``set()`` call)
    passed in a static position of a jit wrapper.  Static args key the
    jit cache — unhashable values raise at dispatch, and mutable ones
    invite aliasing bugs even when tupled later.

``traced-branch``
    Python control flow (``if``/``while``/ternary/``assert``) or
    concretization (``float()``/``int()``/``bool()``/``.item()``/
    ``np.asarray``) on traced values inside policy hot methods
    (``decide``/``update``/``predict``/``observe``/``measure_error``).
    Under ``lax.scan`` these either crash (TracerBoolConversionError)
    or silently bake one branch into the compiled program.  Traced
    roots are the method's array parameters and the traced
    ``StepContext`` fields (``step_idx``/``t_now``/``x``); shape/dtype
    inspection (``.shape``/``.ndim``/``.dtype``/``.size``) is static
    and exempt, as are ``self.*`` attributes (config, not tracers).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, Project

__all__ = ["run"]

# methods that run inside the sampler's trace (lax.scan body)
_HOT_METHODS = {"decide", "update", "predict", "observe", "measure_error"}
# StepContext fields that are traced arrays; the rest (batch,
# feat_shape, crf_dtype) are static python
_TRACED_CTX_FIELDS = {"step_idx", "t_now", "x"}
# static inspection of a traced array — not a concretization
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def run(project: Project, findings: List[Finding]) -> None:
    for mod in project.modules:
        if mod.tree is None:
            continue
        _env_reads(mod, findings)
        jits = _collect_jit_wrappers(mod)
        _static_arg_calls(mod, jits, findings)
        _traced_branches(mod, findings)


# --- env-read-at-import --------------------------------------------------

def _is_environ(node: ast.AST) -> bool:
    """Matches ``os.environ`` (and bare ``environ`` from-imports)."""
    if isinstance(node, ast.Attribute) and node.attr == "environ":
        return isinstance(node.value, ast.Name) and node.value.id == "os"
    return isinstance(node, ast.Name) and node.id == "environ"


def _env_read(node: ast.AST) -> Optional[ast.AST]:
    """Return the offending node if ``node`` reads the environment."""
    if isinstance(node, ast.Call):
        f = node.func
        # os.environ.get(...) / os.getenv(...)
        if isinstance(f, ast.Attribute):
            if f.attr == "get" and _is_environ(f.value):
                return node
            if f.attr == "getenv" and isinstance(f.value, ast.Name) \
                    and f.value.id == "os":
                return node
        if isinstance(f, ast.Name) and f.id == "getenv":
            return node
    if isinstance(node, ast.Subscript) and _is_environ(node.value) \
            and isinstance(node.ctx, ast.Load):
        return node
    return None


def _env_reads(mod: Module, findings: List[Finding]) -> None:
    # walk only import-time code: module body + class bodies, skipping
    # function/lambda bodies (those are call-time by definition)
    def visit_stmts(stmts):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.ClassDef):
                visit_stmts(stmt.body)
                continue
            # reads that feed an os.environ write in the same statement
            # are the sanctioned append-to-XLA_FLAGS shape
            writes_env = any(
                isinstance(t, ast.Subscript) and _is_environ(t.value)
                for t in getattr(stmt, "targets", []))
            for node in ast.walk(stmt):
                if isinstance(node, ast.Lambda):
                    continue
                hit = _env_read(node)
                if hit is None:
                    continue
                if writes_env:
                    continue
                mod.flag(
                    hit, "env-read-at-import",
                    "os.environ read at module import time freezes the "
                    "value for the process; read it at call time "
                    "(accessor fn or module __getattr__, see "
                    "kernels/ops.py)",
                    findings)

    visit_stmts(mod.tree.body)  # type: ignore[union-attr]


# --- unhashable-static-arg -----------------------------------------------

def _is_jax_jit(func: ast.AST) -> bool:
    return (isinstance(func, ast.Attribute) and func.attr == "jit"
            and isinstance(func.value, ast.Name)
            and func.value.id == "jax") or (
        isinstance(func, ast.Name) and func.id == "jit")


def _static_positions(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    """Extract static arg positions/names from a ``jax.jit(...)`` call."""
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in _int_elements(kw.value):
                nums.add(n)
        elif kw.arg == "static_argnames":
            for s in _str_elements(kw.value):
                names.add(s)
    return nums, names


def _int_elements(node: ast.AST):
    nodes = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    for n in nodes:
        if isinstance(n, ast.Constant) and isinstance(n.value, int):
            yield n.value


def _str_elements(node: ast.AST):
    nodes = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    for n in nodes:
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            yield n.value


def _collect_jit_wrappers(mod: Module):
    """Map wrapper name -> (static_argnums, static_argnames, donate).

    Covers ``X = jax.jit(fn, ...)``, ``self.X = jax.jit(fn, ...)`` and
    ``@functools.partial(jax.jit, static_argnames=...)`` decorators.
    Keys are ``"name"`` or ``"self.name"``; decorator-wrapped
    functions are keyed by the function's own name.
    """
    jits: Dict[str, Tuple[Set[int], Set[str], Set[int]]] = {}

    def record(key: str, call: ast.Call, shift: int = 0) -> None:
        nums, names = _static_positions(call)
        donate: Set[int] = set()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                donate.update(_int_elements(kw.value))
        if nums or names or donate:
            jits[key] = ({n + shift for n in nums}, names,
                         {d + shift for d in donate})

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Call) and \
                _is_jax_jit(node.value.func):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    record(tgt.id, node.value)
                elif isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    record(f"self.{tgt.attr}", node.value)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                # @functools.partial(jax.jit, static_argnames=(...))
                if isinstance(dec, ast.Call) and dec.args and \
                        _is_partial(dec.func) and _is_jax_jit(dec.args[0]):
                    record(node.name, dec)
                elif isinstance(dec, ast.Call) and _is_jax_jit(dec.func):
                    record(node.name, dec)
    return jits


def _is_partial(func: ast.AST) -> bool:
    return (isinstance(func, ast.Attribute) and func.attr == "partial") \
        or (isinstance(func, ast.Name) and func.id == "partial")


_UNHASHABLE_CTORS = {"list", "dict", "set", "bytearray"}


def _unhashable(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in _UNHASHABLE_CTORS)


def _call_key(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id == "self":
        return f"self.{f.attr}"
    return None


def _static_arg_calls(mod: Module, jits, findings: List[Finding]) -> None:
    # 1) unhashable literal inside the jit(...) declaration itself is
    #    checked implicitly by the call-site rule; also flag unhashable
    #    values at call sites of known wrappers
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        # direct: jax.jit(fn, static_argnums=[...]) — a *list* is legal
        # python but the elements rule below is about call sites; skip.
        key = _call_key(node)
        if key is None or key not in jits:
            continue
        nums, names, _donate = jits[key]
        for i, arg in enumerate(node.args):
            if i in nums and _unhashable(arg):
                mod.flag(
                    arg, "unhashable-static-arg",
                    f"positional arg {i} of {key}() is static "
                    "(static_argnums) but is an unhashable/mutable "
                    "value; pass a tuple or scalar",
                    findings)
        for kw in node.keywords:
            if kw.arg in names and _unhashable(kw.value):
                mod.flag(
                    kw.value, "unhashable-static-arg",
                    f"keyword {kw.arg!r} of {key}() is static "
                    "(static_argnames) but is an unhashable/mutable "
                    "value; pass a tuple or scalar",
                    findings)


# --- traced-branch -------------------------------------------------------

def _traced_roots(fn: ast.FunctionDef) -> Set[str]:
    """Parameter names treated as traced arrays inside a hot method."""
    roots: Set[str] = set()
    args = fn.args
    for a in list(args.posonlyargs) + list(args.args) + \
            list(args.kwonlyargs):
        if a.arg in ("self", "cls", "ctx"):
            continue
        roots.add(a.arg)
    return roots


class _TracedScan(ast.NodeVisitor):
    def __init__(self, mod: Module, fn: ast.FunctionDef,
                 findings: List[Finding]):
        self.mod = mod
        self.findings = findings
        self.roots = _traced_roots(fn)

    # -- classification ---------------------------------------------------
    def _is_traced(self, node: ast.AST) -> bool:
        """Conservative: does this expression *contain* a traced root
        used as a value (not just its shape/dtype)?"""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in self.roots:
                if not self._static_use(sub, node):
                    return True
            if isinstance(sub, ast.Attribute) and \
                    isinstance(sub.value, ast.Name) and \
                    sub.value.id == "ctx" and \
                    sub.attr in _TRACED_CTX_FIELDS:
                if not self._static_use(sub, node):
                    return True
        return False

    @staticmethod
    def _static_use(leaf: ast.AST, root: ast.AST) -> bool:
        """True when ``leaf`` only ever appears under a static
        attribute access (``x.shape`` etc.) inside ``root``."""
        # find the parent attribute chains containing this exact leaf
        for sub in ast.walk(root):
            if isinstance(sub, ast.Attribute) and sub.value is leaf:
                return sub.attr in _STATIC_ATTRS
        return False

    def _flag(self, node: ast.AST, what: str) -> None:
        self.mod.flag(
            node, "traced-branch",
            f"{what} on a traced value inside a policy hot method; "
            "use lax.cond / jnp.where (see freqca_eb.decide for the "
            "sanctioned adaptive pattern)",
            self.findings)

    # -- visitors ---------------------------------------------------------
    def visit_If(self, node: ast.If) -> None:
        if self._is_traced(node.test):
            self._flag(node, "python `if`")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if self._is_traced(node.test):
            self._flag(node, "python `while`")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        if self._is_traced(node.test):
            self._flag(node, "ternary")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        if self._is_traced(node.test):
            self._flag(node, "assert")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("float", "int", "bool") \
                and node.args and self._is_traced(node.args[0]):
            self._flag(node, f"`{f.id}()`")
        if isinstance(f, ast.Attribute) and f.attr == "item":
            self._flag(node, "`.item()`")
        if isinstance(f, ast.Attribute) and \
                f.attr in ("asarray", "array") and \
                isinstance(f.value, ast.Name) and \
                f.value.id in ("np", "numpy") and \
                node.args and self._is_traced(node.args[0]):
            self._flag(node, f"`np.{f.attr}()`")
        self.generic_visit(node)

    # assignments can retire a root (x = 0 makes x static python)
    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and tgt.id in self.roots \
                    and not self._is_traced(node.value):
                self.roots.discard(tgt.id)

    # nested defs get their own parameter namespace — don't descend
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass


def _traced_branches(mod: Module, findings: List[Finding]) -> None:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for item in node.body:
            if isinstance(item, ast.FunctionDef) and \
                    item.name in _HOT_METHODS:
                # generic_visit: the hot method is itself a FunctionDef
                # and visit() would hit the nested-def no-op
                _TracedScan(mod, item, findings).generic_visit(item)
