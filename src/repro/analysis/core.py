"""Linter core: file discovery, AST parsing, suppressions, reporting.

The unit of work is a :class:`Module` (path + source + AST + suppression
table); a :class:`Project` parses every module once and hands the whole
set to each rule pass, so repo-aware passes (lock graph, jit-wrapper
tables) can see across files without re-parsing.

Suppressions: ``# repro: allow[rule-name]: justification``.  The
justification is mandatory — a bare ``allow[rule]`` is itself reported
(``bad-suppression``), as is an unknown rule name, so suppressions
can't silently rot.  A suppression covers the statement it sits on
(its full ``lineno..end_lineno`` extent when it sits on the first
line); a comment-only line covers the following line.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

__all__ = [
    "Finding", "Module", "Project", "RULES", "analyze_paths",
]

# every rule a pass can emit; suppressions naming anything else are
# flagged as bad-suppression
RULES = (
    "env-read-at-import",
    "unhashable-static-arg",
    "traced-branch",
    "lock-order",
    "future-guard",
    "donated-reuse",
    "bad-suppression",
    "parse-error",
)

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_-]+)\]\s*(?::\s*(\S.*))?$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One reported violation, formatted ``path:line: [rule] message``."""
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class _Suppression:
    rule: str
    line: int            # line the comment sits on
    justification: str
    used: bool = False


class Module:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        # report paths relative to the lint root so CI output is stable
        try:
            self.rel = str(path.relative_to(root))
        except ValueError:
            self.rel = str(path)
        self.source = path.read_text(encoding="utf-8")
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(self.source, filename=self.rel)
        except SyntaxError as e:
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        self.suppressions: List[_Suppression] = []
        self._comment_only: Dict[int, bool] = {}
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _ALLOW_RE.search(tok.string)
                if not m:
                    continue
                rule, why = m.group(1), (m.group(2) or "").strip()
                line = tok.start[0]
                # comment-only line: nothing but whitespace before the #
                only = tok.line[:tok.start[1]].strip() == ""
                self._comment_only[line] = only
                self.suppressions.append(_Suppression(rule, line, why))
        except tokenize.TokenError:
            pass  # parse-error finding already covers a broken file

    def suppressed(self, rule: str, first: int, last: int) -> bool:
        """True if ``rule`` is allowed anywhere on lines first..last,
        or by a comment-only ``allow`` on the line just above."""
        for s in self.suppressions:
            if s.rule != rule:
                continue
            covered = first <= s.line <= last
            if not covered and self._comment_only.get(s.line):
                covered = s.line == first - 1
            if covered:
                s.used = True
                return True
        return False

    def flag(self, node: ast.AST, rule: str, message: str,
             out: List[Finding]) -> None:
        """Report ``rule`` at ``node`` unless a suppression covers it."""
        first = getattr(node, "lineno", 1)
        last = getattr(node, "end_lineno", None) or first
        if not self.suppressed(rule, first, last):
            out.append(Finding(self.rel, first, rule, message))


class Project:
    """All modules under the lint roots, parsed once."""

    def __init__(self, paths: Sequence[Path], root: Path):
        self.root = root
        self.modules: List[Module] = [
            Module(p, root) for p in _discover(paths)]

    def by_name(self, suffix: str) -> Optional[Module]:
        for m in self.modules:
            if m.rel.endswith(suffix):
                return m
        return None


_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", "results",
              ".hypothesis", "build", "dist"}


def _discover(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    seen = set()
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files: Iterable[Path] = [p]
        elif p.is_dir():
            files = sorted(
                f for f in p.rglob("*.py")
                if not any(part in _SKIP_DIRS for part in f.parts))
        else:
            files = []
        for f in files:
            key = f.resolve()
            if key not in seen:
                seen.add(key)
                out.append(f)
    return out


def analyze_paths(paths: Sequence[Path],
                  root: Optional[Path] = None) -> List[Finding]:
    """Run every pass over ``paths``; returns sorted findings."""
    # local imports keep `import repro.analysis` free of ast machinery
    from repro.analysis import donation, locks, recompile

    root = root or Path.cwd()
    project = Project(paths, root)
    findings: List[Finding] = []
    for mod in project.modules:
        if mod.parse_error:
            findings.append(
                Finding(mod.rel, 1, "parse-error", mod.parse_error))
    recompile.run(project, findings)
    locks.run(project, findings)
    donation.run(project, findings)
    _check_suppressions(project, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def _check_suppressions(project: Project,
                        findings: List[Finding]) -> None:
    for mod in project.modules:
        for s in mod.suppressions:
            if s.rule not in RULES:
                findings.append(Finding(
                    mod.rel, s.line, "bad-suppression",
                    f"unknown rule {s.rule!r}; known rules: "
                    + ", ".join(RULES[:-2])))
            elif not s.justification:
                findings.append(Finding(
                    mod.rel, s.line, "bad-suppression",
                    f"allow[{s.rule}] needs a justification: "
                    f"`# repro: allow[{s.rule}]: why`"))


def summarize(findings: Sequence[Finding]) -> str:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    parts = [f"{n} {r}" for r, n in sorted(counts.items())]
    return f"{len(findings)} finding(s): " + ", ".join(parts)
