"""Opt-in runtime sanitizers (``REPRO_SANITIZE=1``).

Two checkers that the static passes can't fully prove:

* **Lock-order sanitizer.**  ``make_lock``/``make_rlock``/
  ``make_condition`` are the serving stack's lock constructors.  With
  sanitizing off (the default) they return plain ``threading``
  primitives — zero overhead, nothing imported beyond ``threading``.
  With ``REPRO_SANITIZE=1`` they return instrumented wrappers that
  maintain (a) a per-thread stack of held locks and (b) a global
  acquisition-order graph (edge ``H -> N`` the first time ``N`` is
  acquired while ``H`` is held).  An ``acquire`` whose edge would
  close a cycle raises :class:`LockOrderError` *before* blocking — the
  test fails with the two offending orders named instead of
  deadlocking until the CI timeout.

* **Tracer-leak sanitizer.**  :func:`check_tracer_leaks` walks a
  pytree-ish object and raises :class:`TracerLeakError` if a
  ``jax.core.Tracer`` escaped into it — the classic symptom of a
  policy stashing a traced value on ``self`` or in a closure during
  ``lax.scan`` tracing.  The engine runs it over the policy signature
  after every dispatch when sanitizing is on.

The env flag is read at *call* time (this module must itself pass the
``env-read-at-import`` rule): tests flip it with ``monkeypatch`` and
construct fresh locks.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.graphs import would_close_cycle

__all__ = [
    "enabled", "make_lock", "make_rlock", "make_condition",
    "LockOrderError", "TracerLeakError", "check_tracer_leaks",
    "order_graph", "reset_order_graph",
]


def enabled() -> bool:
    """Sanitizers on?  Read per call — never frozen at import."""
    return os.environ.get("REPRO_SANITIZE", "") == "1"


class LockOrderError(RuntimeError):
    """A lock acquisition would invert an already-observed order."""


class TracerLeakError(RuntimeError):
    """A jax Tracer escaped the trace into host-side state."""


# --- lock-order sanitizer ------------------------------------------------

# observed acquisition edges: name -> set of names acquired while held
_graph: Dict[str, Set[str]] = {}
_graph_lock = threading.Lock()
_tls = threading.local()


def _held() -> List[Tuple[str, int]]:
    """This thread's stack of (lock name, reentrancy count)."""
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def order_graph() -> Dict[str, Set[str]]:
    """Snapshot of the observed acquisition-order graph (for tests)."""
    with _graph_lock:
        return {k: set(v) for k, v in _graph.items()}


def reset_order_graph() -> None:
    with _graph_lock:
        _graph.clear()


def _before_acquire(name: str) -> None:
    """Record edges held -> name; raise if one would close a cycle.

    Raises *before* the underlying acquire so the offending ``with``
    block never enters and outer locks unwind cleanly.
    """
    stack = _held()
    if any(n == name for n, _ in stack):
        return   # reentrant re-acquire of an RLock: no new edge
    with _graph_lock:
        for held_name, _count in stack:
            if would_close_cycle(_graph, held_name, name):
                # name -> ... -> held_name already observed; adding
                # held_name -> name completes the inversion
                raise LockOrderError(
                    f"lock-order inversion: acquiring {name!r} while "
                    f"holding {held_name!r}, but the opposite order "
                    f"was already observed (graph: "
                    f"{sorted(_graph.get(name, ()))} reachable from "
                    f"{name!r})")
        for held_name, _count in stack:
            _graph.setdefault(held_name, set()).add(name)


def _push(name: str) -> None:
    stack = _held()
    for i, (n, count) in enumerate(stack):
        if n == name:
            stack[i] = (n, count + 1)
            return
    stack.append((name, 1))


def _pop(name: str) -> None:
    stack = _held()
    for i in range(len(stack) - 1, -1, -1):
        n, count = stack[i]
        if n == name:
            if count > 1:
                stack[i] = (n, count - 1)
            else:
                del stack[i]
            return


class _TrackedLock:
    """Instrumented lock: delegates to an inner primitive, maintains
    the held-stack and order graph.  Quacks enough like an ``RLock``
    for ``threading.Condition`` to wrap it (``_release_save`` /
    ``_acquire_restore`` / ``_is_owned``)."""

    def __init__(self, name: str, inner):
        self.name = name
        self._inner = inner

    def acquire(self, blocking: bool = True,
                timeout: float = -1) -> bool:
        _before_acquire(self.name)
        got = self._inner.acquire(blocking, timeout)
        if got:
            _push(self.name)
        return got

    def release(self) -> None:
        self._inner.release()
        _pop(self.name)

    __enter__ = acquire

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # -- Condition integration -------------------------------------------
    # Condition(lock) calls these on wait(): the lock is fully released
    # while waiting, so the held-stack must drop it and re-add it on
    # wake — without re-checking order (a wakeup re-acquire is not a
    # new ordering decision).
    def _release_save(self):
        saver = getattr(self._inner, "_release_save", None)
        state = saver() if saver is not None else self._inner.release()
        _pop(self.name)
        return state

    def _acquire_restore(self, state) -> None:
        restorer = getattr(self._inner, "_acquire_restore", None)
        if restorer is not None:
            restorer(state)
        else:
            self._inner.acquire()
        _push(self.name)

    def _is_owned(self) -> bool:
        owned = getattr(self._inner, "_is_owned", None)
        if owned is not None:
            return owned()
        # plain Lock fallback: owned iff this thread holds it per our
        # own stack (mirrors threading.Condition's acquire(0) trick
        # without perturbing the lock)
        return any(n == self.name for n, _ in _held())

    def __repr__(self) -> str:
        return f"<sanitized {self._inner!r} name={self.name!r}>"


def make_lock(name: str):
    """A ``threading.Lock``, instrumented under ``REPRO_SANITIZE=1``."""
    if not enabled():
        return threading.Lock()
    return _TrackedLock(name, threading.Lock())


def make_rlock(name: str):
    """A ``threading.RLock``, instrumented under ``REPRO_SANITIZE=1``."""
    if not enabled():
        return threading.RLock()
    return _TrackedLock(name, threading.RLock())


def make_condition(name: str, lock=None):
    """A ``threading.Condition``.

    ``lock=None`` builds over a fresh RLock (the ``Scheduler.cv``
    shape); passing a ``make_lock`` result shares that lock's identity
    (the ``FleetRouter._cv`` - over - ``_lock`` shape), matching how
    the static pass aliases ``Condition(self._lock)`` to the lock's
    node.
    """
    if lock is None:
        lock = make_rlock(name) if enabled() else threading.RLock()
    return threading.Condition(lock)


# --- tracer-leak sanitizer -----------------------------------------------

def _tracer_type():
    try:
        import jax
        return jax.core.Tracer
    except Exception:   # jax absent: nothing can leak
        return None


def check_tracer_leaks(obj, label: str = "value",
                       _tracer=None, _seen: Optional[Set[int]] = None,
                       _path: str = "") -> None:
    """Raise :class:`TracerLeakError` if a jax Tracer is reachable from
    ``obj`` through tuples/lists/dicts/namedtuples/dataclasses.

    Cheap by construction — policy signatures are tuples of small
    frozen policy objects — and only wired up under ``enabled()``.
    """
    if _tracer is None:
        _tracer = _tracer_type()
        if _tracer is None:
            return
    if _seen is None:
        _seen = set()
    if id(obj) in _seen:
        return
    _seen.add(id(obj))

    if isinstance(obj, _tracer):
        raise TracerLeakError(
            f"traced value leaked into {label}{_path or ''}: {obj!r} — "
            "a policy stored a tracer on host-side state (self/closure) "
            "during scan tracing; keep traced state in the carry")
    items: Iterable[Tuple[str, object]] = ()
    if isinstance(obj, dict):
        items = [(f"[{k!r}]", v) for k, v in obj.items()]
    elif isinstance(obj, (list, tuple)):
        items = [(f"[{i}]", v) for i, v in enumerate(obj)]
    elif hasattr(obj, "__dataclass_fields__"):
        items = [(f".{f}", getattr(obj, f, None))
                 for f in obj.__dataclass_fields__]
    for suffix, val in items:
        check_tracer_leaks(val, label, _tracer=_tracer, _seen=_seen,
                           _path=_path + suffix)
