"""Tiny directed-graph helpers shared by the static lock-order pass,
the runtime lock sanitizer, and their property tests.

A graph is a ``dict[node, set[node] | iterable[node]]``; nodes absent
from the dict are sinks.  Everything here is iterative (no recursion)
so adversarial inputs from the property tests can't hit the
interpreter's recursion limit.
"""
from __future__ import annotations

from typing import Dict, Hashable, Iterable, List, Optional, Set

Node = Hashable
Graph = Dict[Node, Iterable[Node]]

__all__ = ["find_cycle", "has_path", "would_close_cycle"]


def find_cycle(graph: Graph) -> Optional[List[Node]]:
    """Return one directed cycle as ``[n0, n1, ..., n0]``, or None.

    Deterministic: nodes and successors are visited in the order the
    mapping yields them, so the same graph always reports the same
    cycle (CI output is stable).
    """
    WHITE, GREY, BLACK = 0, 1, 2
    color: Dict[Node, int] = {}
    for root in graph:
        if color.get(root, WHITE) != WHITE:
            continue
        # stack of (node, iterator over successors); path mirrors the
        # grey chain so we can slice the cycle out when we hit it
        stack = [(root, iter(graph.get(root, ())))]
        color[root] = GREY
        path: List[Node] = [root]
        while stack:
            node, it = stack[-1]
            advanced = False
            for succ in it:
                c = color.get(succ, WHITE)
                if c == GREY:
                    return path[path.index(succ):] + [succ]
                if c == WHITE:
                    color[succ] = GREY
                    stack.append((succ, iter(graph.get(succ, ()))))
                    path.append(succ)
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
                path.pop()
    return None


def has_path(graph: Graph, src: Node, dst: Node) -> bool:
    """True if ``dst`` is reachable from ``src`` (0 edges counts:
    ``has_path(g, x, x)`` is always True)."""
    if src == dst:
        return True
    seen: Set[Node] = {src}
    frontier = [src]
    while frontier:
        node = frontier.pop()
        for succ in graph.get(node, ()):
            if succ == dst:
                return True
            if succ not in seen:
                seen.add(succ)
                frontier.append(succ)
    return False


def would_close_cycle(graph: Graph, src: Node, dst: Node) -> bool:
    """True if adding edge ``src -> dst`` would create a cycle.

    The runtime sanitizer calls this *before* recording an acquisition
    edge, so the offending ``acquire`` can be refused while the graph
    still describes only orders that actually happened.
    """
    return has_path(graph, dst, src)
