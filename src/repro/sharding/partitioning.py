"""Logical-axis -> mesh-axis rules and NamedSharding derivation.

Every parameter/cache dim carries a logical axis name (see ParamSpec).
Rules map those names to mesh axes, with divisibility-aware fallbacks:

* tensor parallelism ("model"): ffn / experts / heads; when a head count
  does not divide the 16-way model axis (GQA kv=8, 56-head archs) the
  *head_dim* is sharded instead — the TPU-friendly fallback (DESIGN.md §5).
* FSDP ("data", + "pod" when present): the "embed" dim of weights, so
  >=100B configs fit HBM; GSPMD turns this into per-layer all-gathers.
* batch dims shard over ("pod","data"); the long_500k single-request
  decode shards the KV-cache *length* instead.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DiTConfig, ModelConfig

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def model_rules(cfg: ModelConfig, mesh: Mesh, mode: str,
                serve_tp_bytes: float = 4e9,
                shape_kind: str = "train") -> Rules:
    """mode: 'train' (FSDP+TP) or 'serve' (2D weights + TP).

    ``serve_tp_bytes``: weights above this many bytes per TP shard are
    additionally sharded over the data axis (gathered per layer at
    serve time) — below it they stay TP-resident.

    ``shape_kind``: head_dim sharding (the fallback when a head count
    does not divide the TP axis) is applied ONLY for decode — at
    full-sequence shapes a head_dim-sharded contraction puts an
    all-reduce of the attention logits inside every blockwise tile
    (measured: 30 TB/device on deepseek prefill_32k, §Perf B).
    Full-sequence shapes rely on sequence parallelism instead.
    """
    msz = mesh.shape["model"]
    dp = dp_axes(mesh)
    dpsz = _axis_size(mesh, dp)
    rules: Rules = {
        "layer": None, "heads": None, "head_dim": None, "kv_heads": None,
        "kv_head_dim": None, "ffn": None, "expert": None, "vocab": None,
        "embed": None, "inner": None, "ssm_heads": None,
    }
    # --- tensor parallel placements ---
    if _div(cfg.d_ff, msz):
        rules["ffn"] = "model"
    if cfg.moe is not None and cfg.moe.n_experts > 0:
        if _div(cfg.moe.e_total, msz):
            rules["expert"] = "model"
            rules["ffn"] = None          # experts already split the FFN
    if _div(cfg.n_heads, msz):
        rules["heads"] = "model"
    elif _div(cfg.head_dim, msz) and shape_kind == "decode":
        rules["head_dim"] = "model"
    if _div(cfg.n_kv_heads, msz):
        rules["kv_heads"] = "model"
    elif _div(cfg.head_dim, msz) and shape_kind == "decode":
        rules["kv_head_dim"] = "model"
    if _div(cfg.vocab_size, msz):
        rules["vocab"] = "model"
    if cfg.ssm is not None:
        d_inner = cfg.d_inner
        proj_out = 2 * d_inner + 2 * cfg.ssm.d_state + cfg.n_ssm_heads
        conv_dim = d_inner + 2 * cfg.ssm.d_state
        if all(_div(n, msz) for n in (d_inner, proj_out, conv_dim)):
            rules["inner"] = "model"
        if _div(cfg.n_ssm_heads, msz):
            rules["ssm_heads"] = "model"
    # --- data-axis weight sharding (FSDP / 2D serve weights) ---
    big = param_bytes(cfg) / msz > serve_tp_bytes
    if mode == "train" or big:
        if _div(cfg.d_model, dpsz):
            rules["embed"] = dp
    return rules


def dit_rules(cfg: DiTConfig, mesh: Mesh) -> Rules:
    msz = mesh.shape["model"]
    rules: Rules = {"layer": None, "embed": None, "vocab": None,
                    "heads": None, "head_dim": None, "ffn": None}
    if _div(cfg.d_ff, msz):
        rules["ffn"] = "model"
    if _div(cfg.n_heads, msz):
        rules["heads"] = "model"
    elif _div(cfg.head_dim, msz):
        rules["head_dim"] = "model"
    return rules


def param_bytes(cfg: ModelConfig, bytes_per: int = 2) -> int:
    """Analytic total parameter bytes (no allocation)."""
    from repro.models import common as C
    if cfg.is_encdec:
        from repro.models import encdec
        specs = encdec.encdec_specs(cfg)
    else:
        from repro.models import transformer
        specs = transformer.lm_specs(cfg)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, C.ParamSpec))
    return sum(int(np.prod(s.shape)) * bytes_per for s in leaves)


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: Rules) -> P:
    entries = []
    for name in axes:
        if name is None:
            entries.append(None)
        else:
            entries.append(rules.get(name))
    return P(*entries)


def shardings_for_specs(spec_tree, rules: Rules, mesh: Mesh):
    """ParamSpec tree -> NamedSharding tree."""
    from repro.models.common import ParamSpec

    def one(s: ParamSpec):
        pspec = spec_for_axes(s.axes, rules)
        # drop mesh axes that don't divide the dim (uneven shard guard)
        fixed = []
        for dim, entry in zip(s.shape, pspec, strict=False):
            if entry is None:
                fixed.append(None)
            elif _div(dim, _axis_size(mesh, entry)):
                fixed.append(entry)
            else:
                fixed.append(None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def batch_spec(mesh: Mesh, global_batch: int, ndim: int,
               extra: Tuple = ()) -> NamedSharding:
    dp = dp_axes(mesh)
    if not _div(global_batch, _axis_size(mesh, dp)):
        dp = ("data",) if _div(global_batch, mesh.shape["data"]) else None
    entries = [dp] + [None] * (ndim - 1)
    for i, e in enumerate(extra):
        entries[1 + i] = e
    return NamedSharding(mesh, P(*entries))


def constraint(x, mesh: Mesh, *entries):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
