import os

# Tests run on the single real CPU device; integration tests that need a
# small host-device mesh live in tests/test_dryrun_mesh.py which spawns a
# subprocess with its own XLA_FLAGS (never set the 512-device flag here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
