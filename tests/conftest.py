import os

# Tests run on the single real CPU device; integration tests that need a
# small host-device mesh live in tests/test_dryrun_mesh.py which spawns a
# subprocess with its own XLA_FLAGS (never set the 512-device flag here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

# The "ci" hypothesis profile must exist at pytest-configure time for
# the CI property job's --hypothesis-profile=ci flag; the single
# definition lives in hypothesis_compat (derandomized, deadline=None),
# which also shims st/given for the bare no-hypothesis tier-1 env.
import hypothesis_compat  # noqa: E402,F401
