"""Sharding integration: lower + compile StepSpecs on a small host-device
mesh, in a subprocess (XLA device count is locked at first jax init, so
the 8-device flag must not leak into the other tests)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch import steps as steps_lib
from repro.roofline import hlo_analysis

arch, shape = sys.argv[1], sys.argv[2]
mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    spec = steps_lib.build(arch, shape, mesh)
    compiled = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                       out_shardings=spec.out_shardings,
                       donate_argnums=spec.donate_argnums
                       ).lower(*spec.args).compile()
mem = compiled.memory_analysis()
res = hlo_analysis.analyze(compiled.as_text())
print(json.dumps({
    "temp": mem.temp_size_in_bytes,
    "flops": res["flops"],
    "coll": res["collectives"]["total_bytes"],
}))
"""


def _run(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch, shape],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# one representative per family x step kind keeps CI time sane; the full
# 10x4 sweep runs via `python -m repro.launch.dryrun --all` (EXPERIMENTS.md)
@pytest.mark.parametrize("arch,shape", [
    ("granite-moe-3b-a800m", "decode_32k"),   # MoE + ring-free decode
    ("mamba2-370m", "train_4k"),              # SSM train (SSD scan + bwd)
    ("seamless-m4t-medium", "decode_32k"),    # enc-dec cross-attn decode
    ("yi-9b", "prefill_32k"),                 # dense GQA blockwise prefill
])
def test_lower_compile_small_mesh(arch, shape):
    res = _run(arch, shape)
    assert res["flops"] > 0
    assert res["temp"] > 0
