"""Sharding integration on a small host-device mesh, in subprocesses
(XLA device count is locked at first jax init, so the 8-device flag
must not leak into the other tests):

* lower + compile StepSpecs for representative assigned architectures;
* end-to-end **grouped serving** through the bucketed DiffusionEngine
  on a real 8-way mesh — policy-homogeneous cuts execute with the
  batch sharded over the 4-way data axis (placement asserted shard by
  shard), requests conserved, finite outputs.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.launch import steps as steps_lib
from repro.roofline import hlo_analysis

arch, shape = sys.argv[1], sys.argv[2]
mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    spec = steps_lib.build(arch, shape, mesh)
    compiled = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                       out_shardings=spec.out_shardings,
                       donate_argnums=spec.donate_argnums
                       ).lower(*spec.args).compile()
mem = compiled.memory_analysis()
res = hlo_analysis.analyze(compiled.as_text())
print(json.dumps({
    "temp": mem.temp_size_in_bytes,
    "flops": res["flops"],
    "coll": res["collectives"]["total_bytes"],
}))
"""


def _run_script(script, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script, *args],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _run(arch, shape):
    return _run_script(_SCRIPT, arch, shape)


# one representative per family x step kind keeps CI time sane; the full
# 10x4 sweep runs via `python -m repro.launch.dryrun --all` (EXPERIMENTS.md)
@pytest.mark.parametrize("arch,shape", [
    ("granite-moe-3b-a800m", "decode_32k"),   # MoE + ring-free decode
    ("mamba2-370m", "train_4k"),              # SSM train (SSD scan + bwd)
    ("seamless-m4t-medium", "decode_32k"),    # enc-dec cross-attn decode
    ("yi-9b", "prefill_32k"),                 # dense GQA blockwise prefill
])
def test_lower_compile_small_mesh(arch, shape):
    res = _run(arch, shape)
    assert res["flops"] > 0
    assert res["temp"] > 0


_SERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import repro.configs as config_lib
from repro.core.cache import CachePolicy
from repro.models import common, dit
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.sharding import partitioning

SIZE = 8
assert jax.device_count() == 8
cfg = config_lib.reduced(config_lib.get_config("dit-small"))
params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

def full_fn(x, t):
    tb = jnp.full((x.shape[0],), t)
    out = dit.dit_forward(params, x, tb, cfg)
    return out.velocity, out.crf

def from_crf_fn(crf, t):
    tb = jnp.full((crf.shape[0],), t)
    return dit.dit_from_crf(params, crf, tb, cfg, SIZE, SIZE)

mesh = jax.make_mesh((4, 2), ("data", "model"))
eng = DiffusionEngine(full_fn, from_crf_fn, (SIZE, SIZE, cfg.in_channels),
                      (16, cfg.d_model),
                      CachePolicy(kind="freqca", interval=3),
                      n_steps=6, max_batch=4, mesh=mesh)
assert eng.group_policies and eng.scheduler.group_policies

# sharded batch placement: a full bucket splits over the 4-way data
# axis and replicates over the 2-way model axis -> 8 lane-1 shards
x = eng._place(jnp.zeros((4, SIZE, SIZE, cfg.in_channels)))
want = partitioning.batch_spec(mesh, 4, x.ndim)
assert x.sharding.is_equivalent_to(want, x.ndim), (x.sharding, want)
shards = list(x.addressable_shards)
assert len(shards) == 8
assert all(s.data.shape == (1, SIZE, SIZE, cfg.in_channels)
           for s in shards)

# end-to-end grouped serving: alternating default/fora requests fill
# two compatibility groups -> two policy-pure sharded bucket-4 cuts
fora = CachePolicy(kind="fora", interval=2)
for i in range(8):
    eng.submit(DiffusionRequest(request_id=i, seed=i,
                                policy=fora if i % 2 else None), now=0.0)
outs = eng.serve_until_drained()
s = eng.metrics.summary()
assert sorted(o.request_id for o in outs) == list(range(8))
assert all(jnp.isfinite(o.latents).all() for o in outs)
assert all(o.latents.shape == (SIZE, SIZE, cfg.in_channels) for o in outs)
per_group = s["per_group"]
assert len(per_group) == 2, per_group
assert all(g["requests"] == 4 and g["batches"] == 1
           for g in per_group.values()), per_group
print(json.dumps({
    "devices": jax.device_count(),
    "placement_shards": len(shards),
    "served": len(outs),
    "groups": s["policy_groups"],
    "batches": s["batches"],
    "skip_compute_fraction": s["skip_compute_fraction"],
}))
"""


def test_grouped_serving_on_8way_mesh():
    """ROADMAP multi-host item: the bucketed engine serves a grouped
    mixed-policy stream end to end on a real 8-device mesh, with the
    batch placed over the data axis (asserted shard by shard in the
    subprocess)."""
    res = _run_script(_SERVE_SCRIPT)
    assert res["devices"] == 8
    assert res["placement_shards"] == 8
    assert res["served"] == 8
    assert res["groups"] == 2 and res["batches"] == 2
    assert 0.0 < res["skip_compute_fraction"] < 1.0
