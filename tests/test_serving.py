"""Continuous-batching serving tests: bucket selection, age/deadline
batch formation (incl. the deadline-starvation promotion fix),
padded-lane isolation, the editing noising path, the
zero-steady-state-recompile guarantee (via the jit cache probe), the
threaded async submit path (futures resolve exactly once, ids
conserved, lapsed deadlines served first), and policy-homogeneous
batch formation (compatibility grouping: pure cuts, one warmed ladder
per group, bitwise-golden equivalence against the ungrouped mixed-lane
path — sync and through the async engine under concurrent
submitters)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.core.cache import CachePolicy
from repro.data import synthetic
from repro.diffusion import schedule
from repro.serving import metrics as metrics_lib
from repro.serving.async_engine import AsyncDiffusionEngine
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.scheduler import Scheduler, bucket_for, bucket_sizes

SIZE = 8
N_STEPS = 6


@pytest.fixture(scope="module")
def dit_fns():
    from repro.models import common, dit
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, SIZE, SIZE)

    return cfg, full_fn, from_crf_fn


def make_engine(dit_fns, max_batch=4, n_steps=N_STEPS, **kw):
    cfg, full_fn, from_crf_fn = dit_fns
    return DiffusionEngine(full_fn, from_crf_fn, (SIZE, SIZE,
                                                  cfg.in_channels),
                           (16, cfg.d_model),
                           CachePolicy(kind="freqca", interval=3),
                           n_steps=n_steps, max_batch=max_batch, **kw)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_bucket_sizes_and_selection():
    assert bucket_sizes(8) == [1, 2, 4, 8]
    assert bucket_sizes(6) == [1, 2, 4, 6]   # non-pow2 max still included
    assert bucket_sizes(1) == [1]
    assert bucket_for(1, 8) == 1
    assert bucket_for(3, 8) == 4
    assert bucket_for(5, 8) == 8
    assert bucket_for(5, 6) == 6
    with pytest.raises(ValueError):
        bucket_for(9, 8)
    with pytest.raises(ValueError):
        bucket_for(0, 8)


def test_scheduler_age_based_formation():
    sched = Scheduler(max_batch=4, max_wait_s=10.0, clock=lambda: 0.0)
    sched.submit(DiffusionRequest(request_id=0, seed=0), now=0.0)
    assert not sched.ready(now=1.0)          # young + underfull: hold
    assert sched.form_batch(now=1.0) is None
    assert sched.ready(now=10.0)             # age threshold reached
    plan = sched.form_batch(now=10.0)
    assert plan.n_real == 1 and plan.bucket == 1

    for i in range(4):                        # full largest bucket: cut now
        sched.submit(DiffusionRequest(request_id=i, seed=i), now=11.0)
    assert sched.ready(now=11.0)
    plan = sched.form_batch(now=11.0)
    assert plan.n_real == 4 and plan.bucket == 4 and plan.occupancy == 1.0


def test_scheduler_deadline_and_flush():
    sched = Scheduler(max_batch=8, max_wait_s=100.0, clock=lambda: 0.0)
    sched.submit(DiffusionRequest(request_id=0, seed=0, deadline_s=2.0),
                 now=0.0)
    assert not sched.ready(now=1.0)
    assert sched.ready(now=2.5)               # deadline pressure wins
    # flush drains regardless of age
    sched2 = Scheduler(max_batch=8, max_wait_s=100.0, clock=lambda: 0.0)
    for i in range(3):
        sched2.submit(DiffusionRequest(request_id=i, seed=i), now=0.0)
    plan = sched2.form_batch(now=0.0, flush=True)
    assert plan.n_real == 3 and plan.bucket == 4
    assert len(sched2) == 0


def test_scheduler_deadline_starvation_promotion():
    """Regression: a deadline-lapsed request beyond position max_batch
    used to trigger the cut yet be excluded from it (queue[:take]) —
    under sustained load it could lapse indefinitely.  It must be
    promoted into the cut batch, stable FIFO order otherwise."""
    sched = Scheduler(max_batch=2, max_wait_s=100.0, clock=lambda: 0.0)
    for i in range(2):
        sched.submit(DiffusionRequest(request_id=i, seed=i), now=0.0)
    # lapsed request sits at position 2, beyond max_batch=2
    sched.submit(DiffusionRequest(request_id=2, seed=2, deadline_s=1.0),
                 now=0.0)
    assert sched.ready(now=5.0)
    plan = sched.form_batch(now=5.0)
    ids = [r.request_id for r in plan.requests]
    assert 2 in ids, "lapsed request must be promoted into the cut"
    assert ids == [0, 2]          # stable FIFO order among the picked
    assert [r.request_id for r in sched.queue] == [1]

    # sustained load: fresh undeadlined arrivals keep the queue full —
    # the lapsed request still gets out in the very next cut
    sched2 = Scheduler(max_batch=2, max_wait_s=0.0, clock=lambda: 0.0)
    for i in range(4):
        sched2.submit(DiffusionRequest(request_id=i, seed=i), now=0.0)
    sched2.submit(DiffusionRequest(request_id=9, seed=9, deadline_s=0.5),
                  now=0.0)
    plan = sched2.form_batch(now=2.0)
    assert 9 in [r.request_id for r in plan.requests]


def test_scheduler_seconds_until_ready():
    sched = Scheduler(max_batch=4, max_wait_s=10.0, clock=lambda: 0.0)
    assert sched.seconds_until_ready(now=0.0) is None        # empty queue
    sched.submit(DiffusionRequest(request_id=0, seed=0), now=0.0)
    assert sched.seconds_until_ready(now=2.0) == pytest.approx(8.0)
    sched.submit(DiffusionRequest(request_id=1, seed=1, deadline_s=3.0),
                 now=2.0)
    # deadline (at t=5) beats the age threshold (at t=10)
    assert sched.seconds_until_ready(now=2.0) == pytest.approx(3.0)
    assert sched.seconds_until_ready(now=6.0) == 0.0          # lapsed
    assert sched.ready(now=6.0)


def test_scheduler_thread_safe_submit():
    sched = Scheduler(max_batch=8, max_wait_s=0.0)
    n_threads, per_thread = 8, 50

    def client(k):
        for i in range(per_thread):
            sched.submit(DiffusionRequest(request_id=k * per_thread + i,
                                          seed=0))

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sched.submitted == n_threads * per_thread
    served = []
    while sched.depth:
        served.extend(sched.form_batch(flush=True).requests)
    assert sorted(r.request_id for r in served) == \
        list(range(n_threads * per_thread))


def test_scheduler_pad_to_max_signature():
    sched = Scheduler(max_batch=8, pad_to_max=True)
    sched.submit(DiffusionRequest(request_id=0, seed=0))
    plan = sched.form_batch(flush=True)
    assert plan.bucket == 8 and plan.n_real == 1


def test_scheduler_policy_grouping_and_families():
    """Grouped formation cuts policy-pure batches; compatible static
    families share one group (taylorseer(5) with the freqca(5) default,
    fora(interval=1) with none)."""
    fre = CachePolicy(kind="freqca", interval=5)
    sched = Scheduler(max_batch=4, max_wait_s=0.0, clock=lambda: 0.0,
                      group_policies=True, default_policy=fre)
    pols = [None, CachePolicy(kind="taylorseer", interval=5),
            CachePolicy(kind="fora", interval=1),
            CachePolicy(kind="none")]
    for i, p in enumerate(pols):
        sched.submit(DiffusionRequest(request_id=i, seed=i, policy=p),
                     now=0.0)
    assert len(sched.groups()) == 2
    p1 = sched.form_batch(now=1.0)
    p2 = sched.form_batch(now=1.0)
    assert [r.request_id for r in p1.requests] == [0, 1]
    assert [r.request_id for r in p2.requests] == [2, 3]
    assert p1.group_key != p2.group_key
    assert len(sched) == 0
    # full-group trigger is per group: 3 groups of 2 fill no bucket of 4
    sched2 = Scheduler(max_batch=4, max_wait_s=100.0, clock=lambda: 0.0,
                       group_policies=True, default_policy=fre)
    mixed = [fre, CachePolicy(kind="fora", interval=2),
             CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25)]
    for i in range(6):
        sched2.submit(DiffusionRequest(request_id=i, seed=i,
                                       policy=mixed[i % 3]), now=0.0)
    assert not sched2.ready(now=0.0)
    sched2.submit(DiffusionRequest(request_id=6, seed=6, policy=mixed[0]),
                  now=0.0)
    sched2.submit(DiffusionRequest(request_id=7, seed=7, policy=mixed[0]),
                  now=0.0)
    assert sched2.ready(now=0.0)          # the freqca group is full now
    plan = sched2.form_batch(now=0.0)
    assert [r.request_id for r in plan.requests] == [0, 3, 6, 7]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_padded_lanes_never_leak(dit_fns):
    """A request's output is identical whether it runs alone (bucket 1)
    or padded inside a larger bucket — and pad lanes are never returned."""
    eng = make_engine(dit_fns, max_batch=4)
    for i in range(3):
        eng.submit(DiffusionRequest(request_id=i, seed=i))
    batched = eng.run_batch()                 # 3 real lanes in bucket 4
    assert [o.request_id for o in batched] == [0, 1, 2]
    assert batched[0].bucket == 4
    solo = []
    for i in range(3):
        eng.submit(DiffusionRequest(request_id=i, seed=i))
        solo.extend(eng.run_batch())          # bucket 1, same seeds
    assert solo[0].bucket == 1
    for b, s in zip(batched, solo, strict=True):
        np.testing.assert_allclose(np.asarray(b.latents),
                                   np.asarray(s.latents), atol=1e-5)


def test_editing_request_noising_path(dit_fns):
    cfg = dit_fns[0]
    eng = make_engine(dit_fns, max_batch=4)
    ref = synthetic.shapes_batch(jax.random.key(5), 1, size=SIZE,
                                 channels=cfg.in_channels)[0]
    strength = 0.4
    eng.submit(DiffusionRequest(request_id=0, seed=7, init_latents=ref,
                                edit_strength=strength))
    plan = eng.scheduler.form_batch(flush=True)
    x_init = eng.build_x_init(plan)
    assert x_init.shape[0] == 1               # bucket 1 for a lone request
    noise = jax.random.normal(jax.random.key(7), eng.latent_shape)
    want = schedule.add_noise(ref.astype(noise.dtype), noise, strength)
    np.testing.assert_allclose(np.asarray(x_init[0]), np.asarray(want),
                               atol=1e-6)
    out = eng._execute(plan)
    assert jnp.isfinite(out[0].latents).all()


def test_padding_lanes_are_zero_noise(dit_fns):
    eng = make_engine(dit_fns, max_batch=4)
    for i in range(3):
        eng.submit(DiffusionRequest(request_id=i, seed=i))
    plan = eng.scheduler.form_batch(flush=True)
    x_init = eng.build_x_init(plan)
    assert x_init.shape[0] == 4 and plan.n_real == 3
    np.testing.assert_array_equal(np.asarray(x_init[3]), 0.0)


def test_no_recompile_across_mixed_sizes(dit_fns):
    """Warmup compiles one executable per bucket; serving any mix of
    batch sizes afterwards never grows the jit cache."""
    eng = make_engine(dit_fns, max_batch=4)
    eng.warmup()
    assert eng.compiled_buckets() == len(eng.buckets) == 3
    warm_misses = eng.metrics.compile_misses
    rid = 0
    for _ in range(2):                        # two rounds of mixed sizes
        for burst in (1, 3, 4, 2):
            for _ in range(burst):
                eng.submit(DiffusionRequest(request_id=rid, seed=rid))
                rid += 1
            out = eng.run_batch()
            assert len(out) == burst
    # jit cache probe: still exactly one executable per bucket
    assert eng.compiled_buckets() == len(eng.buckets)
    assert eng.metrics.compile_misses == warm_misses
    assert eng.metrics.compile_hits >= 8
    assert eng.metrics.summary()["mean_occupancy"] <= 1.0


def test_open_loop_poisson_serving(dit_fns):
    """Open-loop client: timestamped Poisson arrivals, batches cut by
    the scheduler's own age pressure (flush=False), everything served."""
    from repro.launch.serve import poisson_stream, serve_open_loop
    eng = make_engine(dit_fns, max_batch=4, max_wait_s=0.01)
    eng.warmup()
    warm_misses = eng.metrics.compile_misses
    plan = poisson_stream(8, rate=200.0, size=SIZE,
                          channels=dit_fns[0].in_channels, edit_every=0)
    outs, wall = serve_open_loop(eng, plan)
    assert sorted(o.request_id for o in outs) == list(range(8))
    assert all(jnp.isfinite(o.latents).all() for o in outs)
    assert eng.metrics.compile_misses == warm_misses   # still zero steady
    assert eng.scheduler.depth == 0


def test_deferred_formation_through_engine(dit_fns):
    eng = make_engine(dit_fns, max_batch=4, max_wait_s=30.0)
    eng.scheduler.clock = lambda: 0.0
    eng.submit(DiffusionRequest(request_id=0, seed=0), now=0.0)
    assert eng.run_batch(flush=False, now=5.0) == []    # held back
    out = eng.run_batch(flush=False, now=31.0)          # age triggers
    assert len(out) == 1 and out[0].queue_wait_s == pytest.approx(31.0)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_percentiles_and_summary():
    m = metrics_lib.ServeMetrics()
    for w in [0.1, 0.2, 0.3, 0.4, 1.0]:
        m.observe_batch(bucket=4, n_real=2, wall_s=w, n_forwards=2,
                        n_steps=10, lane_full=[2, 1])
    m.observe_request(0.0, 0.5, n_full=2)
    m.observe_compile(hit=False)
    m.observe_compile(hit=True)
    m.observe_queue_depth(3)
    s = m.summary()
    assert s["batch_wall_p50_s"] == 0.3
    assert s["batch_wall_p95_s"] == 1.0
    assert s["mean_occupancy"] == 0.5
    assert s["full_step_fraction"] == 0.2
    assert s["request_full_p50"] == 2
    assert s["max_lane_full_spread"] == 1
    assert s["compile_hits"] == 1 and s["compile_misses"] == 1
    assert s["max_queue_depth"] == 3
    assert metrics_lib.throughput(m, 2.0) == 0.5


# ---------------------------------------------------------------------------
# per-lane policies
# ---------------------------------------------------------------------------

def test_mixed_policy_batch_per_lane_accounting(dit_fns):
    """The ISSUE-2 acceptance path (ungrouped mixed-lane former): one
    lane freqca_a, one lane fora in the same batch -> per-request
    n_full_steps differ, each lane's latents match its solo-batch run,
    and the mixed signature serves with zero steady-state recompiles
    once warm."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=12,
                      group_policies=False)
    pol_a = CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25)
    pol_b = CachePolicy(kind="fora", interval=2)
    lanes = (pol_a, pol_b)
    warm_s = eng.warmup(buckets=[1], lane_policy_sets=[lanes])
    assert warm_s > 0 and eng.metrics.compile_misses >= 2

    def submit_pair():
        eng.submit(DiffusionRequest(request_id=0, seed=0, policy=pol_a))
        eng.submit(DiffusionRequest(request_id=1, seed=1, policy=pol_b))
        return eng.run_batch()

    out = submit_pair()
    assert [o.request_id for o in out] == [0, 1]
    # per-request activated-step counts decouple across lanes
    assert out[0].n_full_steps != out[1].n_full_steps
    assert eng.metrics.summary()["max_lane_full_spread"] > 0

    # each lane matches its solo (bucket-1, uniform-policy) run
    for o, pol in zip(out, lanes, strict=True):
        eng.submit(DiffusionRequest(request_id=o.request_id,
                                    seed=o.request_id, policy=pol))
        solo = eng.run_batch()[0]
        assert solo.n_full_steps == o.n_full_steps
        np.testing.assert_allclose(np.asarray(o.latents),
                                   np.asarray(solo.latents), atol=1e-5)

    # steady state: every signature seen so far is warm — repeated
    # mixed-policy batches never recompile
    warm_misses = eng.metrics.compile_misses
    for _ in range(2):
        submit_pair()
    assert eng.metrics.compile_misses == warm_misses


def test_uniform_nondefault_policy_collapses_signature(dit_fns):
    """All lanes on the same non-default policy -> single-policy jit
    signature (one compile), not a per-lane tuple per bucket."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=6)
    eng.warmup()
    misses = eng.metrics.compile_misses
    pol = CachePolicy(kind="fora", interval=3)
    for rep in range(2):
        for i in range(2):
            eng.submit(DiffusionRequest(request_id=i, seed=i, policy=pol))
        out = eng.run_batch()
        assert len(out) == 2
    # one new executable for the fora signature, reused on the repeat
    assert eng.metrics.compile_misses == misses + 1


# ---------------------------------------------------------------------------
# policy-homogeneous grouping (golden equivalence vs the ungrouped path)
# ---------------------------------------------------------------------------

MIXED_POLS = (None,                                  # engine default
              CachePolicy(kind="fora", interval=2),
              CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25))


def _mixed_requests(n=6):
    return [DiffusionRequest(request_id=i, seed=i,
                             policy=MIXED_POLS[i % len(MIXED_POLS)])
            for i in range(n)]


@pytest.fixture(scope="module")
def ungrouped_baseline(dit_fns):
    """The PR-2 mixed-lane path: per-request results of the reference
    stream served without grouping (mixed batches, per-lane masks)."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=8,
                      group_policies=False)
    for r in _mixed_requests():
        eng.submit(r, now=0.0)
    return {o.request_id: o for o in eng.serve_until_drained()}


def test_grouped_golden_equivalence(dit_fns, ungrouped_baseline):
    """Grouped serving of the same mixed-policy stream: policy-pure
    cuts, compile-free after one warmed ladder per group, signatures
    within the groups x buckets budget — and bitwise-identical
    per-request outputs to the ungrouped path."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=8)
    assert eng.group_policies and eng.scheduler.group_policies
    eng.warmup(policies=[p for p in MIXED_POLS if p is not None])
    warm_misses = eng.metrics.compile_misses
    for r in _mixed_requests():
        eng.submit(r, now=0.0)
    outs = eng.serve_until_drained()
    s = eng.metrics.summary()
    # three policy-pure cuts of two lanes each
    assert s["policy_groups"] == 3
    assert all(g["batches"] == 1 and g["requests"] == 2
               for g in s["per_group"].values())
    # compile-free serving; the probe stays within the grouped budget
    assert eng.metrics.compile_misses == warm_misses
    assert s["compiled_signatures"] <= 3 * len(eng.buckets)
    # bitwise golden vs the ungrouped mixed-lane path
    assert sorted(o.request_id for o in outs) == \
        sorted(ungrouped_baseline)
    for o in outs:
        base = ungrouped_baseline[o.request_id]
        assert o.n_full_steps == base.n_full_steps
        np.testing.assert_array_equal(np.asarray(o.latents),
                                      np.asarray(base.latents))


def test_grouped_async_concurrent_submitters_golden(dit_fns,
                                                    ungrouped_baseline):
    """The same stream through ``AsyncDiffusionEngine`` over a grouped
    engine, submitted from concurrent client threads: every future
    resolves to the bitwise result of the ungrouped sync path, with
    zero steady-state recompiles."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=8, max_wait_s=0.005)
    eng.warmup(policies=[p for p in MIXED_POLS if p is not None])
    warm_misses = eng.metrics.compile_misses
    reqs = _mixed_requests()
    futures, lock = {}, threading.Lock()
    with AsyncDiffusionEngine(eng) as aeng:
        def client(k):
            for i in range(k, len(reqs), 3):
                fut = aeng.submit(reqs[i])
                with lock:
                    futures[i] = fut

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert aeng.drain(timeout=120)
    assert eng.metrics.compile_misses == warm_misses
    assert sorted(futures) == sorted(ungrouped_baseline)
    for i, fut in futures.items():
        res = fut.result(timeout=0)
        base = ungrouped_baseline[i]
        assert res.request_id == i
        assert res.n_full_steps == base.n_full_steps
        np.testing.assert_array_equal(np.asarray(res.latents),
                                      np.asarray(base.latents))


def test_family_batch_composition_signature(dit_fns):
    """A static-family cut mixing distinct member policies (fora(1) +
    none: identical activation masks) executes correctly and keys the
    jit cache by CANONICAL composition — re-serving the same
    composition under a different arrival interleaving adds zero
    compiles, and each lane bitwise-matches its solo run."""
    eng = make_engine(dit_fns, max_batch=2, n_steps=6)
    fora1 = CachePolicy(kind="fora", interval=1)
    none = CachePolicy(kind="none")
    assert eng.scheduler.group_key(
        DiffusionRequest(request_id=0, seed=0, policy=fora1)) == \
        eng.scheduler.group_key(
            DiffusionRequest(request_id=0, seed=0, policy=none))

    def serve_pair(pol0, pol1):
        eng.submit(DiffusionRequest(request_id=0, seed=0, policy=pol0))
        eng.submit(DiffusionRequest(request_id=1, seed=1, policy=pol1))
        out = eng.run_batch()      # one family batch: the group is full
        assert len(out) == 2
        return {o.request_id: o for o in out}

    out1 = serve_pair(fora1, none)
    misses = eng.metrics.compile_misses
    serve_pair(none, fora1)        # reversed interleaving, same mix
    assert eng.metrics.compile_misses == misses
    # family lanes bitwise-match their solo (bucket-1, uniform) runs
    for rid, pol in [(0, fora1), (1, none)]:
        eng.submit(DiffusionRequest(request_id=rid, seed=rid, policy=pol))
        solo = eng.run_batch()[0]
        assert solo.n_full_steps == out1[rid].n_full_steps
        np.testing.assert_array_equal(np.asarray(out1[rid].latents),
                                      np.asarray(solo.latents))


# ---------------------------------------------------------------------------
# async engine
# ---------------------------------------------------------------------------

def test_async_submit_returns_future_immediately(dit_fns):
    eng = make_engine(dit_fns, max_batch=2, max_wait_s=0.0)
    eng.warmup()
    with AsyncDiffusionEngine(eng) as aeng:
        fut = aeng.submit(DiffusionRequest(request_id=7, seed=7))
        res = fut.result(timeout=60)
        assert res.request_id == 7
        assert jnp.isfinite(res.latents).all()
        assert fut.done()
    # post-shutdown submits are refused, worker is stopped
    with pytest.raises(RuntimeError):
        aeng.submit(DiffusionRequest(request_id=8, seed=8))
    s = eng.metrics.summary()
    assert s["time_to_first_result_s"] is not None


def test_async_stress_many_client_threads(dit_fns):
    """N client threads submitting concurrently against a small ladder:
    every future resolves exactly once, request ids are conserved, zero
    steady-state recompiles, nothing lost or double-served."""
    eng = make_engine(dit_fns, max_batch=4, max_wait_s=0.005)
    eng.warmup()
    warm_misses = eng.metrics.compile_misses
    n_threads, per_thread = 4, 6
    results, results_lock = [], threading.Lock()
    futures = []

    def on_done(f):
        with results_lock:
            results.append(f.result(timeout=0))

    with AsyncDiffusionEngine(eng) as aeng:
        def client(k):
            futs = []
            for i in range(per_thread):
                rid = k * per_thread + i
                fut = aeng.submit(DiffusionRequest(request_id=rid, seed=rid))
                fut.add_done_callback(on_done)
                futs.append(fut)
            with results_lock:
                futures.extend(futs)

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert aeng.drain(timeout=120)

    total = n_threads * per_thread
    assert len(futures) == total
    # exactly-once: every future done, each id appears exactly once
    assert all(f.done() for f in futures)
    got = sorted(f.result(timeout=0).request_id for f in futures)
    assert got == list(range(total))
    # done-callbacks fired exactly once per future too
    assert sorted(r.request_id for r in results) == list(range(total))
    # ladder was warm: serving added zero steady-state recompiles
    assert eng.metrics.compile_misses == warm_misses
    assert eng.scheduler.depth == 0
    assert eng.metrics.summary()["requests"] == total


def test_async_deadline_lapsed_served_first(dit_fns):
    """While the worker is busy, the queue overflows max_batch; when the
    next batch is cut, the deadline-lapsed request is promoted into it
    ahead of an earlier undeadlined one — which keeps waiting under the
    long age threshold until drain."""
    eng = make_engine(dit_fns, max_batch=2, max_wait_s=30.0)
    eng.warmup()
    aeng = AsyncDiffusionEngine(eng).start()
    try:
        # fills the largest bucket -> cut at once, worker goes busy
        fa = aeng.submit(DiffusionRequest(request_id=10, seed=10))
        fb = aeng.submit(DiffusionRequest(request_id=11, seed=11))
        # these three land while the worker executes: queue > max_batch
        f2 = aeng.submit(DiffusionRequest(request_id=2, seed=2))
        f3 = aeng.submit(DiffusionRequest(request_id=3, seed=3))
        f4 = aeng.submit(DiffusionRequest(request_id=4, seed=4,
                                          deadline_s=0.0))   # lapses now
        # next cut is [2, 4]: the lapsed request jumps FIFO position 3
        assert f4.result(timeout=60).request_id == 4
        assert f2.result(timeout=60).request_id == 2
        assert fa.result(timeout=60).request_id == 10
        assert fb.result(timeout=60).request_id == 11
        assert not f3.done()       # still held back by the age threshold
    finally:
        aeng.shutdown(drain=True, timeout=120)
    assert f3.result(timeout=0).request_id == 3   # drained on shutdown


def test_async_client_cancel_does_not_kill_worker(dit_fns):
    """A client cancelling a still-queued future must not crash the
    worker when its batch is cut (the lane still runs; the cancelled
    future just never gets a result) — later requests keep serving."""
    eng = make_engine(dit_fns, max_batch=2, max_wait_s=0.0)
    eng.warmup()
    with AsyncDiffusionEngine(eng) as aeng:
        # keep the worker busy so the next submits stay queued
        f0 = aeng.submit(DiffusionRequest(request_id=0, seed=0))
        f1 = aeng.submit(DiffusionRequest(request_id=1, seed=1))
        f2 = aeng.submit(DiffusionRequest(request_id=2, seed=2))
        cancelled = f2.cancel()    # races the cut: either way is legal
        f3 = aeng.submit(DiffusionRequest(request_id=3, seed=3))
        assert f3.result(timeout=60).request_id == 3   # worker alive
        assert f0.result(timeout=60).request_id == 0
        assert f1.result(timeout=60).request_id == 1
        if cancelled:
            assert f2.cancelled()
        else:
            assert f2.result(timeout=60).request_id == 2
    # duplicate submission of the same pending object is refused
    eng2 = make_engine(dit_fns, max_batch=2, max_wait_s=30.0)
    eng2.warmup(buckets=[1])
    aeng2 = AsyncDiffusionEngine(eng2).start()
    try:
        req = DiffusionRequest(request_id=0, seed=0)
        aeng2.submit(req)
        with pytest.raises(ValueError):
            aeng2.submit(req)
    finally:
        aeng2.shutdown(drain=True, timeout=120)


def test_async_shutdown_without_drain_cancels_queued(dit_fns):
    eng = make_engine(dit_fns, max_batch=2, max_wait_s=30.0)
    eng.warmup()
    aeng = AsyncDiffusionEngine(eng).start()
    fut = aeng.submit(DiffusionRequest(request_id=0, seed=0))
    aeng.shutdown(drain=False, timeout=120)
    # either served before the stop landed, or cancelled — never lost
    assert fut.done()
    if not fut.cancelled():
        assert fut.result(timeout=0).request_id == 0
    assert eng.scheduler.depth == 0
