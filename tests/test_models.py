"""Model substrate tests: decode==full equivalence, CRF identity, SSD
chunked==naive, MoE dispatch semantics, blockwise attention == dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models import attention, blocks, common, moe, ssm, transformer


def tiny_cfg(**kw):
    base = {"arch_id": "tiny", "family": "dense", "n_layers": 2,
            "d_model": 64, "n_heads": 4, "n_kv_heads": 2, "d_ff": 128,
            "vocab_size": 256, "head_dim": 16, "dtype": "float32",
            "remat": False}
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.key(1), (2, 16), 0, 256)


def _decode_matches_full(cfg, toks, atol=2e-4):
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    full = transformer.forward(params, toks, cfg)
    cache = blocks.stack_cache_zeros(cfg, toks.shape[0], toks.shape[1],
                                     jnp.float32)
    outs = []
    for i in range(toks.shape[1]):
        lg, cache = transformer.decode_step(params, toks[:, i:i + 1], cache,
                                            cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full.logits),
                               atol=atol)
    return full


def test_dense_decode_matches_full(toks):
    _decode_matches_full(tiny_cfg(), toks)


def test_ssm_decode_matches_full(toks):
    cfg = tiny_cfg(family="ssm", d_ff=0, n_kv_heads=4,
                   ssm=SSMConfig(d_state=16, head_dim=16, chunk=8))
    _decode_matches_full(cfg, toks, atol=1e-3)


def test_hybrid_decode_matches_full(toks):
    cfg = tiny_cfg(family="hybrid", n_layers=8, attn_every=8, d_ff=64,
                   moe=MoEConfig(n_experts=4, top_k=2, every=2,
                                 capacity_factor=8.0),
                   ssm=SSMConfig(d_state=16, head_dim=16, chunk=8))
    _decode_matches_full(cfg, toks, atol=1e-3)


def test_sliding_window_decode_matches_full(toks):
    cfg = tiny_cfg(sliding_window=8)
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    full = transformer.forward(params, toks, cfg)
    # ring cache sized exactly one window
    cache = blocks.stack_cache_zeros(cfg, 2, 8, jnp.float32)
    outs = []
    for i in range(16):
        lg, cache = transformer.decode_step(params, toks[:, i:i + 1], cache,
                                            cfg, window=8)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full.logits),
                               atol=2e-4)


def test_crf_equals_embedding_plus_residuals(toks):
    """The CRF is literally h0 + sum of residual updates (paper §3.2.2)."""
    cfg = tiny_cfg()
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    out = transformer.forward(params, toks, cfg)
    # recompute manually, accumulating residual deltas
    h = common.embed(params["embed"], toks).astype(jnp.float32)
    h0 = h
    total = jnp.zeros_like(h)
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[layer], params["stack"]["l0"])
        h_new, _ = blocks.block_full(lp, h, cfg, "attn", False)
        total = total + (h_new - h)
        h = h_new
    # scan vs unrolled differ by f32 reassociation only -> relative tol
    np.testing.assert_allclose(np.asarray(h0 + total), np.asarray(out.crf),
                               rtol=3e-3, atol=2e-3)


def test_blockwise_attention_matches_dense():
    b, s, hq, hkv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(jax.random.key(1), (b, s, hq, hd))
    k = jax.random.normal(jax.random.key(2), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.key(3), (b, s, hkv, hd))
    for window in (0, 24):
        ref = attention._sdpa(q, k, v, attention.causal_mask(s, window),
                              hq // hkv)
        out = attention.blockwise_sdpa(q, k, v, hq // hkv, window=window,
                                       q_block=16, kv_block=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


def test_ssd_chunked_matches_naive():
    from repro.kernels import ref as kref
    b, s, h, p, n = 2, 64, 4, 32, 16
    xs = jax.random.normal(jax.random.key(2), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(3), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.key(4), (h,)) * 0.3)
    B = jax.random.normal(jax.random.key(5), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.key(6), (b, s, n)) * 0.5
    y_naive, st_naive = kref.ssd_naive_ref(xs, dt, A, B, C)
    for chunk in (8, 16, 32, 64):
        y_chunk, st_chunk = ssm.ssd_chunked(xs, dt, A, B, C, chunk)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                                   atol=2e-4, err_msg=f"chunk={chunk}")
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_naive),
                               atol=2e-4)


def test_moe_matches_dense_reference():
    """Einsum-dispatch MoE == per-token loop when capacity is unlimited."""
    cfg = tiny_cfg(family="moe", d_ff=32,
                   moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=16.0))
    params = common.init_params(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 64))
    y, aux = moe.moe_ffn(params, x, cfg)
    assert float(aux.drop_fraction) == 0.0

    # reference: explicit per-token top-k mixture
    flat = x.reshape(-1, 64)
    logits = flat @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_v, top_i = jax.lax.top_k(probs, 2)
    top_v = top_v / top_v.sum(-1, keepdims=True)
    ref = jnp.zeros_like(flat)
    for t in range(flat.shape[0]):
        acc = jnp.zeros((64,))
        for j in range(2):
            e = int(top_i[t, j])
            h = jax.nn.silu(flat[t] @ params["wi_gate"][e]) * \
                (flat[t] @ params["wi_up"][e])
            acc += top_v[t, j] * (h @ params["wo"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 64)),
                               np.asarray(ref), atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = tiny_cfg(family="moe", d_ff=32,
                   moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=0.5))
    params = common.init_params(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, 64))
    _, aux = moe.moe_ffn(params, x, cfg)
    assert float(aux.drop_fraction) > 0.0


def test_encdec_decode_matches_full():
    from repro.models import encdec
    cfg = tiny_cfg(family="audio", is_encdec=True, n_enc_layers=2,
                   n_kv_heads=4)
    p = common.init_params(encdec.encdec_specs(cfg), jax.random.key(0))
    frames = jax.random.normal(jax.random.key(2), (2, 24, 64))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 256)
    out = encdec.forward(p, frames, toks, cfg)
    cache = encdec.decode_cache_zeros(cfg, 2, 12, jnp.float32)
    dec = []
    for i in range(12):
        lg, cache = encdec.decode_step(p, toks[:, i:i + 1], out.memory,
                                       cache, cfg)
        dec.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(dec, 1)),
                               np.asarray(out.logits), atol=2e-4)


def test_chunked_ce_matches_dense():
    cfg = tiny_cfg()
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 256)
    labels = jnp.concatenate(
        [toks[:, 1:], -jnp.ones((2, 1), jnp.int32)], axis=1)
    out = transformer.forward(params, toks, cfg)
    hn = common.rmsnorm(params["final_norm"], out.crf, cfg.norm_eps)
    chunked = transformer.chunked_cross_entropy(params, hn, labels, cfg,
                                                chunk=8)
    logp = jax.nn.log_softmax(out.logits.astype(jnp.float32), -1)
    valid = labels >= 0
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    dense = jnp.sum(nll * valid) / jnp.sum(valid)
    np.testing.assert_allclose(float(chunked), float(dense), rtol=1e-5)
