"""Integration: cached diffusion sampling end-to-end on a tiny DiT.

Validates the paper's qualitative claims at smoke scale:
* all policies produce finite samples and the scheduled FLOPs saving,
* FreqCa's prediction error vs the uncached trajectory is no worse than
  FORA's (reuse) at the same interval,
* the layer-wise variant and CRF variant produce comparable errors
  (Fig 4) while CRF uses ~1% of the memory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.core import cache as cache_lib
from repro.core.cache import CachePolicy
from repro.diffusion import sampler, schedule
from repro.models import common, dit


@pytest.fixture(scope="module")
def tiny_dit():
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, 8, 8)

    x0 = jax.random.normal(jax.random.key(1), (2, 8, 8, cfg.in_channels))
    return cfg, full_fn, from_crf_fn, x0


@pytest.mark.parametrize("kind", ["none", "fora", "taylorseer", "foca",
                                  "freqca"])
def test_policies_sample_finite(tiny_dit, kind):
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    pol = CachePolicy(kind=kind, interval=5, method="dct", rho=0.25)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=(2, 16, cfg.d_model))
    assert bool(jnp.isfinite(res.x).all())
    if kind == "none":
        assert int(res.n_full) == 20
    else:
        # 4 scheduled + warmup fills
        assert int(res.n_full) < 20


def test_speedup_matches_interval(tiny_dit):
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    n_steps = 50
    ts = schedule.timesteps(n_steps)
    pol = CachePolicy(kind="freqca", interval=5, method="dct")
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=(2, 16, cfg.d_model))
    # paper: speedup ~ N as C_pred -> 0; 50 steps at N=5 -> 10 + warmup 2
    assert int(res.n_full) <= n_steps // 5 + 3


def test_freqca_not_worse_than_fora(tiny_dit):
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(30)
    ref = sampler.sample(full_fn, from_crf_fn, x0, ts,
                         CachePolicy(kind="none"),
                         crf_shape=(2, 16, cfg.d_model))

    def err(kind, **kw):
        pol = CachePolicy(kind=kind, interval=5, method="dct", rho=0.25,
                          **kw)
        res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                             crf_shape=(2, 16, cfg.d_model))
        return float(jnp.mean(jnp.square(res.x - ref.x)))

    e_freqca = err("freqca")
    e_fora = err("fora")
    assert np.isfinite(e_freqca) and np.isfinite(e_fora)
    assert e_freqca <= e_fora * 1.5, (e_freqca, e_fora)


def test_reference_features_trajectory(tiny_dit):
    cfg, full_fn, _, x0 = tiny_dit
    ts = schedule.timesteps(8)
    x, xs, crfs = sampler.reference_features(full_fn, x0, ts)
    assert xs.shape[0] == 8 and crfs.shape[0] == 8
    assert bool(jnp.isfinite(crfs).all())


def test_layerwise_vs_crf_prediction():
    """Fig-4 semantics: predicting the summed residuals (CRF) ~ as good
    as summing per-layer predictions, at a fraction of the memory."""
    rng = jax.random.key(0)
    n_layers, feat = 6, (1, 8, 4)
    pol = CachePolicy(kind="taylorseer", high_order=2)

    def layer_traj(t):  # smooth per-layer residuals
        base = jnp.arange(n_layers, dtype=jnp.float32)[:, None, None, None]
        return (base + 1.0) * (t ** 2) * jnp.ones((n_layers,) + feat)

    h0 = jnp.zeros(feat)
    lw = cache_lib.layerwise_init(pol, n_layers, feat)
    crf_pol = CachePolicy(kind="taylorseer", high_order=2)
    crf = cache_lib.init_state(crf_pol, feat)
    for t in [1.0, 0.8, 0.6]:
        lw = cache_lib.layerwise_update(pol, lw, layer_traj(t), t)
        crf = cache_lib.update(crf_pol, crf, h0 + layer_traj(t).sum(0), t)
    want = h0 + layer_traj(0.4).sum(0)
    pred_lw = cache_lib.layerwise_predict(pol, lw, 0.4, h0)
    pred_crf = cache_lib.predict(crf_pol, crf, 0.4)
    np.testing.assert_allclose(np.asarray(pred_lw), np.asarray(want),
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(pred_crf), np.asarray(want),
                               atol=1e-2)


def test_teacache_adaptive_compute(tiny_dit):
    """TeaCache: lower threshold -> more full steps (monotone knob)."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    import jax, jax.numpy as jnp
    # perturb nothing: use the trained-enough fixture; thresholds sweep
    ts = schedule.timesteps(20)
    fulls = []
    for th in (0.01, 1e9):
        pol = CachePolicy(kind="teacache", tea_threshold=th)
        res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                             crf_shape=(2, 16, cfg.d_model))
        fulls.append(int(res.n_full))
        assert bool(jnp.isfinite(res.x).all())
    assert fulls[0] >= fulls[1]
