"""Unit tests for the ``repro.analysis`` invariant linter.

Each of the three rule families gets both directions: the rule FIRES on
a minimal seeded violation, and stays SILENT on the repo's sanctioned
pattern for the same situation (call-time env reads, lax.cond-style
decisions, the router's exactly-once future guard, consistent lock
order).  The repo itself must lint clean — that's a test here, not just
a CI step, so a PR that introduces a violation fails tier-1 locally.
"""
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis.core import analyze_paths

REPO = Path(__file__).resolve().parent.parent


def lint(tmp_path, source, name="snippet.py"):
    """Lint one snippet; returns the list of (rule, line) pairs."""
    f = tmp_path / name
    f.write_text(source)
    return [(x.rule, x.line) for x in analyze_paths([f], root=tmp_path)]


def rules(findings):
    return {r for r, _ in findings}


# ---------------------------------------------------------------------------
# family 1: recompile hazards
# ---------------------------------------------------------------------------

def test_env_read_at_import_fires(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "MODE = os.environ.get('REPRO_MODE', 'x')\n"
        "SIZE = int(os.getenv('SIZE', '1'))\n"
        "RAW = os.environ['HOME']\n"
    ))
    assert [r for r, _ in found] == ["env-read-at-import"] * 3
    assert [ln for _, ln in found] == [2, 3, 4]


def test_env_read_sanctioned_patterns_silent(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "def mode():\n"                       # call-time accessor
        "    return os.environ.get('M', 'x')\n"
        "def __getattr__(name):\n"            # PEP 562 lazy attr
        "    return os.environ.get(name, '')\n"
        "os.environ.setdefault('JAX_PLATFORMS', 'cpu')\n"   # write
        "os.environ['XLA_FLAGS'] = ('--foo ' \n"
        "    + os.environ.get('XLA_FLAGS', ''))\n"  # read feeding write
    ))
    assert found == []


def test_env_read_in_class_body_fires(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "class C:\n"
        "    FLAG = os.environ.get('F', '')\n"
    ))
    assert rules(found) == {"env-read-at-import"}


def test_unhashable_static_arg_fires_and_tuple_is_fine(tmp_path):
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('cfg',))\n"
        "def run(x, cfg=None):\n"
        "    return x\n"
        "def bad():\n"
        "    return run(1, cfg=[1, 2])\n"
        "def good():\n"
        "    return run(1, cfg=(1, 2))\n"
        "wrapped = jax.jit(lambda x, n: x, static_argnums=1)\n"
        "def bad2():\n"
        "    return wrapped(1, {'a': 1})\n"
    )
    found = lint(tmp_path, src)
    assert [r for r, _ in found] == ["unhashable-static-arg"] * 2
    assert [ln for _, ln in found] == [6, 11]


def test_traced_branch_fires_on_if_float_item(tmp_path):
    found = lint(tmp_path, (
        "class Pol:\n"
        "    def decide(self, step, t):\n"
        "        if step > 3:\n"
        "            return 1.0\n"
        "        return float(t)\n"
        "    def update(self, x):\n"
        "        return x.item()\n"
    ))
    assert [r for r, _ in found] == ["traced-branch"] * 3
    assert [ln for _, ln in found] == [3, 5, 7]


def test_traced_branch_sanctioned_patterns_silent(tmp_path):
    # the real policies' shapes: config ifs on self.*, shape/dtype
    # inspection of traced args, jnp.where data-dependence, and
    # dispatch-layer calls (ops.use_pallas()) — all static, all fine
    found = lint(tmp_path, (
        "import jax.numpy as jnp\n"
        "from repro.kernels import ops\n"
        "class Pol:\n"
        "    high_order = 2\n"
        "    def decide(self, crf, acc):\n"
        "        if self._fusable(crf.shape[1:]):\n"
        "            return self.fused(crf)\n"
        "        if ops.use_pallas() and self.high_order > 0:\n"
        "            return 1\n"
        "        return jnp.where(acc > 0.5, 1.0, 0.0)\n"
        "    def _fusable(self, shape):\n"
        "        return len(shape) == 2\n"
        "    def fused(self, crf):\n"
        "        return crf\n"
    ))
    assert found == []


def test_traced_branch_only_scans_hot_methods(tmp_path):
    # helper methods may branch on their args (called outside the scan)
    found = lint(tmp_path, (
        "class Pol:\n"
        "    def resolve(self, n):\n"
        "        if n > 3:\n"
        "            return 1\n"
        "        return 0\n"
    ))
    assert found == []


# ---------------------------------------------------------------------------
# family 2: lock discipline
# ---------------------------------------------------------------------------

_LOCK_CYCLE = (
    "import threading\n"
    "class A:\n"
    "    def __init__(self):\n"
    "        self.l1 = threading.Lock()\n"
    "class B:\n"
    "    def __init__(self, a: A):\n"
    "        self.a = a\n"
    "        self.l2 = threading.Lock()\n"
    "    def fwd(self):\n"
    "        with self.l2:\n"
    "            with self.a.l1:\n"
    "                pass\n"
    "    def rev(self):\n"
    "        with self.a.l1:\n"
    "            with self.l2:\n"
    "                pass\n"
)


def test_lock_order_inversion_fires(tmp_path):
    found = lint(tmp_path, _LOCK_CYCLE)
    assert rules(found) == {"lock-order"}


def test_lock_order_consistent_nesting_silent(tmp_path):
    consistent = _LOCK_CYCLE.replace(
        "    def rev(self):\n"
        "        with self.a.l1:\n"
        "            with self.l2:\n",
        "    def rev(self):\n"
        "        with self.l2:\n"
        "            with self.a.l1:\n")
    assert lint(tmp_path, consistent) == []


def test_lock_order_sees_through_calls(tmp_path):
    # the inversion hides behind a method call: B holds l2 and calls
    # a.take() which acquires l1; A.back() holds l1 and calls b.grab()
    found = lint(tmp_path, (
        "import threading\n"
        "class A:\n"
        "    def __init__(self):\n"
        "        self.l1 = threading.Lock()\n"
        "    def take(self):\n"
        "        with self.l1:\n"
        "            pass\n"
        "class B:\n"
        "    def __init__(self, a: A):\n"
        "        self.a = a\n"
        "        self.l2 = threading.Lock()\n"
        "    def grab(self):\n"
        "        with self.l2:\n"
        "            pass\n"
        "    def fwd(self):\n"
        "        with self.l2:\n"
        "            self.a.take()\n"
        "    def rev(self):\n"
        "        with self.a.l1:\n"
        "            self.grab()\n"
    ))
    assert rules(found) == {"lock-order"}


def test_condition_over_lock_aliases_to_one_node(tmp_path):
    # the FleetRouter shape: _cv wraps _lock, so nesting `with self._cv`
    # around helpers that take `with self._lock` is reentrant, not an
    # inversion (and vice versa)
    found = lint(tmp_path, (
        "import threading\n"
        "class R:\n"
        "    def __init__(self):\n"
        "        self.lk = threading.Lock()\n"
        "        self.cv = threading.Condition(self.lk)\n"
        "    def f(self):\n"
        "        with self.cv:\n"
        "            with self.lk:\n"
        "                pass\n"
    ))
    assert found == []


def test_future_guard_fires_unguarded(tmp_path):
    found = lint(tmp_path, (
        "def resolve(fut, res):\n"
        "    fut.set_result(res)\n"
        "def fail(fut, e):\n"
        "    fut.set_exception(e)\n"
    ))
    assert [r for r, _ in found] == ["future-guard"] * 2


def test_future_guard_sanctioned_patterns_silent(tmp_path):
    # the two repo idioms: try/except InvalidStateError (router) and
    # an `if ... not fut.done()` / set_running_or_notify_cancel guard
    found = lint(tmp_path, (
        "from concurrent.futures import InvalidStateError\n"
        "def resolve(fut, res, counters):\n"
        "    try:\n"
        "        fut.set_result(res)\n"
        "    except InvalidStateError:\n"
        "        counters['duplicate_results'] += 1\n"
        "def fail(fut, e):\n"
        "    if fut is not None and not fut.done():\n"
        "        fut.set_exception(e)\n"
        "def start(fut, res):\n"
        "    if fut.set_running_or_notify_cancel():\n"
        "        fut.set_result(res)\n"
    ))
    assert found == []


# ---------------------------------------------------------------------------
# family 3: donation
# ---------------------------------------------------------------------------

def test_donated_reuse_fires(tmp_path):
    found = lint(tmp_path, (
        "import jax\n"
        "step = jax.jit(lambda x: x + 1, donate_argnums=0)\n"
        "def use(x):\n"
        "    y = step(x)\n"
        "    return x + y\n"     # x's buffer belongs to XLA now
    ))
    assert [r for r, _ in found] == ["donated-reuse"]
    assert found[0][1] == 5


def test_donated_rebind_is_silent(tmp_path):
    found = lint(tmp_path, (
        "import jax\n"
        "step = jax.jit(lambda x: x + 1, donate_argnums=0)\n"
        "def loop(x):\n"
        "    for _ in range(3):\n"
        "        x = step(x)\n"   # rebinding revives the name
        "    return x\n"
    ))
    assert found == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_with_justification_silences(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "# repro: allow[env-read-at-import]: frozen on purpose, "
        "build id\n"
        "BUILD = os.environ.get('BUILD_ID', '')\n"
    ))
    assert found == []


def test_suppression_on_same_line_silences(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "B = os.environ.get('B', '')"
        "  # repro: allow[env-read-at-import]: frozen on purpose\n"
    ))
    assert found == []


def test_bare_suppression_is_itself_flagged(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "# repro: allow[env-read-at-import]\n"
        "BUILD = os.environ.get('BUILD_ID', '')\n"
    ))
    # the allow silences the read but is flagged for missing its why
    assert [r for r, _ in found] == ["bad-suppression"]


def test_unknown_rule_suppression_is_flagged(tmp_path):
    found = lint(tmp_path, (
        "x = 1  # repro: allow[no-such-rule]: whatever\n"
    ))
    assert [r for r, _ in found] == ["bad-suppression"]


def test_suppression_does_not_leak_to_other_rules(tmp_path):
    found = lint(tmp_path, (
        "import os\n"
        "# repro: allow[traced-branch]: wrong rule name for this line\n"
        "BUILD = os.environ.get('BUILD_ID', '')\n"
    ))
    assert rules(found) == {"env-read-at-import"}


# ---------------------------------------------------------------------------
# satellite: benchmark env knobs are call-time, not import-frozen
# ---------------------------------------------------------------------------

def test_bench_env_reads_are_call_time(monkeypatch):
    from benchmarks import common as B

    monkeypatch.delenv("BENCH_IMG_SIZE", raising=False)
    monkeypatch.delenv("BENCH_REDUCED", raising=False)
    assert B.IMG_SIZE == 32
    assert B.CKPT_DIR == "results/bench_ckpt"
    # flipping env AFTER import must change what the module reports —
    # this is exactly what the frozen module constants got wrong
    monkeypatch.setenv("BENCH_IMG_SIZE", "16")
    monkeypatch.setenv("BENCH_REDUCED", "1")
    assert B.IMG_SIZE == 16
    assert B.REDUCED is True
    assert B.CKPT_DIR == "results/bench_ckpt_smoke"


def test_parse_error_is_reported_not_raised(tmp_path):
    found = lint(tmp_path, "def broken(:\n")
    assert [r for r, _ in found] == ["parse-error"]


# ---------------------------------------------------------------------------
# the repo itself lints clean (the CI gate, as a tier-1 test)
# ---------------------------------------------------------------------------

def test_repo_lints_clean():
    findings = analyze_paths(
        [REPO / "src", REPO / "tests", REPO / "benchmarks"], root=REPO)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import os\nM = os.environ.get('M', '')\n")
    env_root = dict(os.environ)
    env_root["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env_root.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad)],
        capture_output=True, text=True, env=env_root)
    assert r.returncode == 1
    assert "env-read-at-import" in r.stdout
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules"],
        capture_output=True, text=True, env=env_root)
    assert r.returncode == 0
    assert "lock-order" in r.stdout
