"""Shared hypothesis import shim + the single "ci" profile definition.

Real ``st``/``given`` when hypothesis is installed (CI's
``pip install -e .[dev]``); in the bare tier-1 environment the shim
turns every ``@given`` test into a graceful ``importorskip`` while the
deterministic tests in the same modules keep running.

The "ci" profile lives HERE and nowhere else: ``deadline=None`` so
shrinking a failure can't blow the CI job timeout, ``derandomize=True``
so every run — the tier-1 sweep and the dedicated
``--hypothesis-profile=ci`` property job — draws the same examples.
``tests/conftest.py`` imports this module, which registers the profile
before pytest-configure resolves ``--hypothesis-profile``.
"""
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=25, derandomize=True,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()   # strategy expressions in decorators still eval

    def given(*a, **k):
        def deco(fn):
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            return skipper
        return deco
