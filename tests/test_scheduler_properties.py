"""Property-based serving-invariant harness for ``Scheduler.form_batch``.

A model-based simulation drives arbitrary request streams — mixed
policies (including compatible static-schedule families), deadlines,
arrival gaps, interleaved cut attempts, and a final drain — through the
scheduler in both formation modes, then checks the serving invariants
on the full cut history:

* **conservation** — no request is dropped or duplicated across cuts;
* **stable FIFO within a compatibility group** — a request served
  while not deadline-lapsed is never overtaken by a later submission of
  its own group (ungrouped: of the whole queue), and every batch lists
  its requests in submission order;
* **deadline promotion** — whenever a batch is cut while lapsed
  requests exist, the cut is taken from the group of the most-overdue
  one and contains its lapsed members up to ``max_batch`` (ungrouped:
  the FIFO-first lapsed requests), so a lapsed request is served by the
  very next cut of its group and can never be starved;
* **policy purity** — under ``group_policies=True`` every emitted
  batch is policy-homogeneous (one compatibility key), and the plan's
  ``group_key`` matches its members;
* **bucketing** — ``bucket`` is a ladder signature that fits
  ``n_real`` (exactly ``bucket_for`` unless ``pad_to_max``).

The same checker runs under Hypothesis (the CI property job:
``--hypothesis-profile=ci --hypothesis-seed=0``) and on deterministic
regression streams that exercise each invariant without hypothesis
installed (the bare tier-1 environment).
"""
import dataclasses

import pytest

from repro.core.cache import CachePolicy
from repro.serving.scheduler import (DiffusionRequest, Scheduler,
                                     bucket_for, bucket_sizes)

# property tests skip gracefully when hypothesis is absent (CI installs
# it via `pip install -e .[dev]`); the deterministic twins below drive
# the same checker either way.  The derandomized "ci" profile and the
# no-hypothesis shim live in hypothesis_compat.
from hypothesis_compat import given, st  # noqa: E402


DEFAULT = CachePolicy(kind="freqca", interval=5)
# deliberately includes compatible static families: taylorseer(5) keys
# with freqca(5) — same (interval, needed_history) — and fora(1) keys
# with none (both activate every step)
POLICIES = [
    None,                                            # -> engine default
    CachePolicy(kind="taylorseer", interval=5),      # same key as DEFAULT
    CachePolicy(kind="fora", interval=2),
    CachePolicy(kind="fora", interval=1),            # same key as "none"
    CachePolicy(kind="none"),
    CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25),
    CachePolicy(kind="teacache", tea_threshold=0.2),
]


@dataclasses.dataclass
class Cut:
    plan: object
    # request_ids lapsed anywhere in the queue at cut time, queue order
    lapsed_before: list
    queue_before: list          # request_ids queued at cut time


def drive(actions, max_batch, max_wait_s, grouped, pad_to_max=False):
    """Replay a generated action stream; return (submitted, cuts, sched).

    ``actions``: sequence of ("submit", gap_s, policy_idx, deadline_s)
    and ("cut", gap_s) tuples, on a fake monotonically advancing clock;
    the stream always ends with a flush drain (every queue empties).
    """
    t = [0.0]
    sched = Scheduler(max_batch=max_batch, max_wait_s=max_wait_s,
                      pad_to_max=pad_to_max, clock=lambda: t[0],
                      group_policies=grouped, default_policy=DEFAULT)
    submitted, cuts, rid = [], [], 0

    def attempt(flush):
        lapsed = [sched.queue[i].request_id for i in sched._lapsed(t[0])]
        queued = [r.request_id for r in sched.queue]
        plan = sched.form_batch(flush=flush)
        if plan is not None:
            cuts.append(Cut(plan=plan, lapsed_before=lapsed,
                            queue_before=queued))
        return plan

    for act in actions:
        t[0] += act[1]
        if act[0] == "submit":
            req = DiffusionRequest(request_id=rid, seed=rid,
                                   policy=POLICIES[act[2]],
                                   deadline_s=act[3])
            sched.submit(req)
            submitted.append(req)
            rid += 1
        else:
            attempt(flush=False)
    guard = 0
    while len(sched):
        assert attempt(flush=True) is not None   # flush always cuts
        guard += 1
        assert guard <= len(submitted), "drain did not terminate"
    return submitted, cuts, sched


def check_invariants(submitted, cuts, sched, max_batch, grouped,
                     pad_to_max=False):
    by_id = {r.request_id: r for r in submitted}
    key_of = {r.request_id: sched.group_key(r) for r in submitted}

    # conservation: every submitted request served exactly once
    served = [r.request_id for c in cuts for r in c.plan.requests]
    assert sorted(served) == sorted(by_id), "dropped/duplicated requests"

    fifo_tail: dict = {}   # group key -> last non-promoted rid served
    for c in cuts:
        ids = [r.request_id for r in c.plan.requests]
        if grouped:
            # canonical lane order: policy values in sorted blocks so
            # the jit signature keys on the composition, stable
            # submission order within each value
            vals = [repr(r.policy if r.policy is not None else DEFAULT)
                    for r in c.plan.requests]
            assert vals == sorted(vals), "lane order not canonical"
            last: dict = {}
            for v, i in zip(vals, ids, strict=True):
                assert last.get(v, -1) < i, "FIFO broken within value"
                last[v] = i
        else:
            # ungrouped batches list members in stable submission order
            assert ids == sorted(ids)
        # bucketing: a ladder signature that fits the real lanes
        assert c.plan.bucket in bucket_sizes(max_batch)
        want = (max_batch if pad_to_max
                else bucket_for(len(ids), max_batch))
        assert c.plan.bucket == want

        if grouped:
            # policy purity: one compatibility group per batch
            keys = {key_of[i] for i in ids}
            assert keys == {c.plan.group_key}, \
                f"mixed-policy batch under grouping: {keys}"

        # deadline promotion: a cut taken while lapsed requests exist
        # comes from the most-overdue request's group and contains its
        # lapsed members up to max_batch
        if c.lapsed_before:
            now = c.plan.formed_at
            overdue = {i: now - by_id[i].submit_time - by_id[i].deadline_s
                       for i in c.lapsed_before}
            worst = max(overdue.values())
            if grouped:
                worst_keys = {key_of[i] for i, v in overdue.items()
                              if v == worst}
                assert c.plan.group_key in worst_keys
                in_group = [i for i in c.lapsed_before
                            if key_of[i] == c.plan.group_key]
            else:
                in_group = list(c.lapsed_before)
            expect = in_group[:min(len(in_group), max_batch)]
            assert set(expect) <= set(ids), \
                f"lapsed {expect} missing from the next cut {ids}"

        # stable FIFO within a group ACROSS cuts: a non-promoted request
        # is never served in a later cut than a younger one of its own
        # group (promoted = lapsed at its cut time; lanes inside one
        # cut run simultaneously, so canonical lane order is exempt)
        non_promoted = [i for i in ids if i not in c.lapsed_before]
        for i in non_promoted:
            k = key_of[i] if grouped else None
            assert fifo_tail.get(k, -1) < i, \
                f"request {i} overtook FIFO order in group {k}"
        for i in non_promoted:
            k = key_of[i] if grouped else None
            fifo_tail[k] = max(fifo_tail.get(k, -1), i)


def run_case(actions, max_batch, max_wait_s, grouped, pad_to_max=False):
    submitted, cuts, sched = drive(actions, max_batch, max_wait_s,
                                   grouped, pad_to_max)
    check_invariants(submitted, cuts, sched, max_batch, grouped,
                     pad_to_max)
    return submitted, cuts


# ---------------------------------------------------------------------------
# hypothesis property suite (the CI job)
# ---------------------------------------------------------------------------

def _actions():
    gap = st.floats(min_value=0.0, max_value=0.3, allow_nan=False,
                    allow_infinity=False)
    deadline = st.one_of(st.none(),
                         st.floats(min_value=0.0, max_value=0.5,
                                   allow_nan=False, allow_infinity=False))
    submit = st.tuples(st.just("submit"), gap,
                       st.integers(0, len(POLICIES) - 1), deadline)
    cut = st.tuples(st.just("cut"), gap)
    return st.lists(st.one_of(submit, cut), min_size=1, max_size=48)


@given(_actions(), st.integers(1, 8), st.sampled_from([0.0, 0.05, 1e9]),
       st.booleans())
def test_invariants_hold_for_arbitrary_streams(actions, max_batch,
                                               max_wait_s, grouped):
    """The full invariant set, grouped and ungrouped, any stream."""
    run_case(actions, max_batch, max_wait_s, grouped)


@given(_actions(), st.integers(1, 8))
def test_invariants_hold_with_pad_to_max(actions, max_batch):
    run_case(actions, max_batch, max_wait_s=0.01, grouped=True,
             pad_to_max=True)


@given(_actions(), st.integers(1, 4))
def test_grouped_and_ungrouped_serve_identical_request_sets(actions,
                                                            max_batch):
    """Grouping changes batch composition, never the served set."""
    sub_g, cuts_g = run_case(actions, max_batch, 0.05, grouped=True)
    sub_u, cuts_u = run_case(actions, max_batch, 0.05, grouped=False)
    assert sorted(r.request_id for c in cuts_g for r in c.plan.requests) \
        == sorted(r.request_id for c in cuts_u for r in c.plan.requests)


# ---------------------------------------------------------------------------
# deterministic twins (run in the bare tier-1 env, no hypothesis)
# ---------------------------------------------------------------------------

def _mixed_stream_actions():
    acts = []
    for i in range(16):
        acts.append(("submit", 0.01, i % len(POLICIES),
                     0.2 if i % 5 == 4 else None))
        if i % 3 == 2:
            acts.append(("cut", 0.05))
    acts.append(("cut", 1.0))
    return acts


@pytest.mark.parametrize("grouped", [False, True])
@pytest.mark.parametrize("max_batch", [1, 3, 4])
def test_deterministic_mixed_stream(grouped, max_batch):
    run_case(_mixed_stream_actions(), max_batch, max_wait_s=0.05,
             grouped=grouped)


def test_deterministic_pad_to_max():
    run_case(_mixed_stream_actions(), 4, max_wait_s=0.0, grouped=True,
             pad_to_max=True)


def test_deterministic_deadline_burst():
    """Lapsed requests across *different* groups: each is promoted into
    the very next cut of its group, most-overdue group first."""
    acts = [("submit", 0.0, 1, None), ("submit", 0.0, 2, None),
            ("submit", 0.0, 2, 0.10),       # fora(2): lapses second
            ("submit", 0.0, 5, 0.05),       # freqca_a: most overdue
            ("cut", 0.2)]
    submitted, cuts = run_case(acts, 8, max_wait_s=1e9, grouped=True)
    # first cut: the most-overdue lapsed request's (adaptive) group
    assert [r.request_id for r in cuts[0].plan.requests] == [3]
    # second: the other lapsed group, its lapsed member promoted
    assert [r.request_id for r in cuts[1].plan.requests] == [1, 2]


def test_deterministic_rare_group_not_starved():
    """A busy group keeps its bucket full; the rare policy's request is
    served as soon as it heads the queue and ages past max_wait."""
    acts = [("submit", 0.0, 5, None)]                 # rare adaptive
    acts += [("submit", 0.0, 2, None)] * 8            # busy fora group
    acts += [("cut", 0.0)]                            # full-bucket cut
    acts += [("submit", 0.0, 2, None)] * 4            # keeps arriving
    acts += [("cut", 0.2)]                            # rare head aged
    submitted, cuts = run_case(acts, 4, max_wait_s=0.1, grouped=True)
    # cut 1 at t=0: fora bucket full, rare head still young -> fora
    assert all(r.request_id != 0 for r in cuts[0].plan.requests)
    # cut 2 at t=0.2: age pressure -> the rare request's own group
    assert [r.request_id for r in cuts[1].plan.requests] == [0]


def test_deterministic_static_families_share_batches():
    """taylorseer(5)/freqca(5) and fora(1)/none key together: one batch
    each, never one per distinct policy object."""
    acts = [("submit", 0.0, 0, None), ("submit", 0.0, 1, None),
            ("submit", 0.0, 3, None), ("submit", 0.0, 4, None),
            ("cut", 0.2)]
    submitted, cuts = run_case(acts, 8, max_wait_s=0.05, grouped=True)
    assert len(cuts) == 2
    assert [r.request_id for r in cuts[0].plan.requests] == [0, 1]
    assert [r.request_id for r in cuts[1].plan.requests] == [2, 3]
