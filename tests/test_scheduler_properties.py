"""Property-based serving-invariant harness for ``Scheduler.form_batch``.

A model-based simulation drives arbitrary request streams — mixed
policies (including compatible static-schedule families), deadlines,
arrival gaps, interleaved cut attempts, and a final drain — through the
scheduler in both formation modes, then checks the serving invariants
on the full cut history:

* **conservation** — no request is dropped or duplicated across cuts;
* **stable FIFO within a compatibility group** — a request served
  while not deadline-lapsed is never overtaken by a later submission of
  its own group (ungrouped: of the whole queue), and every batch lists
  its requests in submission order;
* **deadline promotion** — whenever a batch is cut while lapsed
  requests exist, the cut is taken from the group of the most-overdue
  one and contains its lapsed members up to ``max_batch`` (ungrouped:
  the FIFO-first lapsed requests), so a lapsed request is served by the
  very next cut of its group and can never be starved;
* **policy purity** — under ``group_policies=True`` every emitted
  batch is policy-homogeneous (one compatibility key), and the plan's
  ``group_key`` matches its members;
* **shape purity** — in EVERY mode (mixed shapes cannot share one
  executable) each cut resolves to a single (latent, CRF) shape key,
  the plan carries it, and deadline promotion never leaks across
  shapes: the promoted set is the lapsed members of the cut's own
  (shape, group), so a lapsed 512-token request can never be pulled
  into a 256-token batch;
* **bucketing** — ``bucket`` is a ladder signature that fits
  ``n_real`` (exactly ``bucket_for`` unless ``pad_to_max``).

The same checker runs under Hypothesis (the CI property job:
``--hypothesis-profile=ci --hypothesis-seed=0``) and on deterministic
regression streams that exercise each invariant without hypothesis
installed (the bare tier-1 environment).
"""
import dataclasses

import pytest

from repro.core.cache import CachePolicy
from repro.serving.scheduler import (DiffusionRequest, Scheduler,
                                     bucket_for, bucket_sizes)

# property tests skip gracefully when hypothesis is absent (CI installs
# it via `pip install -e .[dev]`); the deterministic twins below drive
# the same checker either way.  The derandomized "ci" profile and the
# no-hypothesis shim live in hypothesis_compat.
from hypothesis_compat import given, st  # noqa: E402


DEFAULT = CachePolicy(kind="freqca", interval=5)
# deliberately includes compatible static families: taylorseer(5) keys
# with freqca(5) — same (interval, needed_history) — and fora(1) keys
# with none (both activate every step)
POLICIES = [
    None,                                            # -> engine default
    CachePolicy(kind="taylorseer", interval=5),      # same key as DEFAULT
    CachePolicy(kind="fora", interval=2),
    CachePolicy(kind="fora", interval=1),            # same key as "none"
    CachePolicy(kind="none"),
    CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25),
    CachePolicy(kind="teacache", tea_threshold=0.2),
]
# multi-resolution streams: (latent, CRF) shape pairs a request may
# declare; None = undeclared (the engine-default pseudo-shape)
SHAPES = [
    None,
    ((8, 8, 4), (16, 64)),
    ((16, 16, 4), (64, 64)),
    ((32, 32, 4), (256, 64)),
]


@dataclasses.dataclass
class Cut:
    plan: object
    # request_ids lapsed anywhere in the queue at cut time, queue order
    lapsed_before: list
    queue_before: list          # request_ids queued at cut time


def drive(actions, max_batch, max_wait_s, grouped, pad_to_max=False):
    """Replay a generated action stream; return (submitted, cuts, sched).

    ``actions``: sequence of ("submit", gap_s, policy_idx, deadline_s)
    — optionally with a trailing shape index into ``SHAPES`` — and
    ("cut", gap_s) tuples, on a fake monotonically advancing clock;
    the stream always ends with a flush drain (every queue empties).
    """
    t = [0.0]
    sched = Scheduler(max_batch=max_batch, max_wait_s=max_wait_s,
                      pad_to_max=pad_to_max, clock=lambda: t[0],
                      group_policies=grouped, default_policy=DEFAULT)
    submitted, cuts, rid = [], [], 0

    def attempt(flush):
        lapsed = [sched.queue[i].request_id for i in sched._lapsed(t[0])]
        queued = [r.request_id for r in sched.queue]
        plan = sched.form_batch(flush=flush)
        if plan is not None:
            cuts.append(Cut(plan=plan, lapsed_before=lapsed,
                            queue_before=queued))
        return plan

    for act in actions:
        t[0] += act[1]
        if act[0] == "submit":
            shape = SHAPES[act[4]] if len(act) > 4 else None
            req = DiffusionRequest(request_id=rid, seed=rid,
                                   policy=POLICIES[act[2]],
                                   deadline_s=act[3],
                                   latent_shape=shape and shape[0],
                                   crf_shape=shape and shape[1])
            sched.submit(req)
            submitted.append(req)
            rid += 1
        else:
            attempt(flush=False)
    guard = 0
    while len(sched):
        assert attempt(flush=True) is not None   # flush always cuts
        guard += 1
        assert guard <= len(submitted), "drain did not terminate"
    return submitted, cuts, sched


def _plan_cut_key(plan, grouped):
    """The (shape, group) cut key a plan claims for itself."""
    shape = (None if plan.latent_shape is None
             else (tuple(plan.latent_shape), tuple(plan.crf_shape)))
    return (shape, plan.group_key if grouped else None)


def check_invariants(submitted, cuts, sched, max_batch, grouped,
                     pad_to_max=False):
    by_id = {r.request_id: r for r in submitted}
    # the scheduler's own (shape, group) cut key: purity, promotion,
    # and FIFO are all scoped to it (shape folds in unconditionally)
    key_of = {r.request_id: sched._cut_key(r) for r in submitted}

    # conservation: every submitted request served exactly once
    served = [r.request_id for c in cuts for r in c.plan.requests]
    assert sorted(served) == sorted(by_id), "dropped/duplicated requests"

    fifo_tail: dict = {}   # cut key -> last non-promoted rid served
    for c in cuts:
        ids = [r.request_id for r in c.plan.requests]
        plan_key = _plan_cut_key(c.plan, grouped)
        # shape purity in EVERY mode: one shape key per cut, and the
        # plan carries it
        assert {key_of[i] for i in ids} == {plan_key}, \
            f"impure cut {ids}: {[key_of[i] for i in ids]} != {plan_key}"
        if grouped:
            # canonical lane order: policy values in sorted blocks so
            # the jit signature keys on the composition, stable
            # submission order within each value
            vals = [repr(r.policy if r.policy is not None else DEFAULT)
                    for r in c.plan.requests]
            assert vals == sorted(vals), "lane order not canonical"
            last: dict = {}
            for v, i in zip(vals, ids, strict=True):
                assert last.get(v, -1) < i, "FIFO broken within value"
                last[v] = i
        else:
            # ungrouped batches list members in stable submission order
            assert ids == sorted(ids)
        # bucketing: a ladder signature that fits the real lanes
        assert c.plan.bucket in bucket_sizes(max_batch)
        want = (max_batch if pad_to_max
                else bucket_for(len(ids), max_batch))
        assert c.plan.bucket == want

        if grouped:
            # policy purity: one compatibility group per batch
            keys = {sched.group_key(by_id[i]) for i in ids}
            assert keys == {c.plan.group_key}, \
                f"mixed-policy batch under grouping: {keys}"

        # deadline promotion: a cut taken while lapsed requests exist
        # comes from the most-overdue request's (shape, group) and
        # contains ITS lapsed members up to max_batch — promotion never
        # leaks a lapsed request into a cut of another shape or group
        if c.lapsed_before:
            now = c.plan.formed_at
            overdue = {i: now - by_id[i].submit_time - by_id[i].deadline_s
                       for i in c.lapsed_before}
            worst = max(overdue.values())
            worst_keys = {key_of[i] for i, v in overdue.items()
                          if v == worst}
            assert plan_key in worst_keys, \
                f"cut {plan_key} ignored most-overdue {worst_keys}"
            in_group = [i for i in c.lapsed_before
                        if key_of[i] == plan_key]
            expect = in_group[:min(len(in_group), max_batch)]
            assert set(expect) <= set(ids), \
                f"lapsed {expect} missing from the next cut {ids}"

        # stable FIFO within a (shape, group) ACROSS cuts: a
        # non-promoted request is never served in a later cut than a
        # younger one of its own cut key (promoted = lapsed at its cut
        # time; lanes inside one cut run simultaneously, so canonical
        # lane order is exempt)
        non_promoted = [i for i in ids if i not in c.lapsed_before]
        for i in non_promoted:
            assert fifo_tail.get(plan_key, -1) < i, \
                f"request {i} overtook FIFO order in {plan_key}"
        for i in non_promoted:
            fifo_tail[plan_key] = max(fifo_tail.get(plan_key, -1), i)


def run_case(actions, max_batch, max_wait_s, grouped, pad_to_max=False):
    submitted, cuts, sched = drive(actions, max_batch, max_wait_s,
                                   grouped, pad_to_max)
    check_invariants(submitted, cuts, sched, max_batch, grouped,
                     pad_to_max)
    return submitted, cuts


# ---------------------------------------------------------------------------
# hypothesis property suite (the CI job)
# ---------------------------------------------------------------------------

def _actions():
    gap = st.floats(min_value=0.0, max_value=0.3, allow_nan=False,
                    allow_infinity=False)
    deadline = st.one_of(st.none(),
                         st.floats(min_value=0.0, max_value=0.5,
                                   allow_nan=False, allow_infinity=False))
    # every submit carries a shape index too (0 = undeclared), so the
    # whole property suite runs over (batch, seq)-mixed streams
    submit = st.tuples(st.just("submit"), gap,
                       st.integers(0, len(POLICIES) - 1), deadline,
                       st.integers(0, len(SHAPES) - 1))
    cut = st.tuples(st.just("cut"), gap)
    return st.lists(st.one_of(submit, cut), min_size=1, max_size=48)


@given(_actions(), st.integers(1, 8), st.sampled_from([0.0, 0.05, 1e9]),
       st.booleans())
def test_invariants_hold_for_arbitrary_streams(actions, max_batch,
                                               max_wait_s, grouped):
    """The full invariant set, grouped and ungrouped, any stream."""
    run_case(actions, max_batch, max_wait_s, grouped)


@given(_actions(), st.integers(1, 8))
def test_invariants_hold_with_pad_to_max(actions, max_batch):
    run_case(actions, max_batch, max_wait_s=0.01, grouped=True,
             pad_to_max=True)


@given(_actions(), st.integers(1, 4))
def test_grouped_and_ungrouped_serve_identical_request_sets(actions,
                                                            max_batch):
    """Grouping changes batch composition, never the served set."""
    sub_g, cuts_g = run_case(actions, max_batch, 0.05, grouped=True)
    sub_u, cuts_u = run_case(actions, max_batch, 0.05, grouped=False)
    assert sorted(r.request_id for c in cuts_g for r in c.plan.requests) \
        == sorted(r.request_id for c in cuts_u for r in c.plan.requests)


# ---------------------------------------------------------------------------
# deterministic twins (run in the bare tier-1 env, no hypothesis)
# ---------------------------------------------------------------------------

def _mixed_stream_actions():
    acts = []
    for i in range(16):
        acts.append(("submit", 0.01, i % len(POLICIES),
                     0.2 if i % 5 == 4 else None))
        if i % 3 == 2:
            acts.append(("cut", 0.05))
    acts.append(("cut", 1.0))
    return acts


@pytest.mark.parametrize("grouped", [False, True])
@pytest.mark.parametrize("max_batch", [1, 3, 4])
def test_deterministic_mixed_stream(grouped, max_batch):
    run_case(_mixed_stream_actions(), max_batch, max_wait_s=0.05,
             grouped=grouped)


def test_deterministic_pad_to_max():
    run_case(_mixed_stream_actions(), 4, max_wait_s=0.0, grouped=True,
             pad_to_max=True)


def test_deterministic_deadline_burst():
    """Lapsed requests across *different* groups: each is promoted into
    the very next cut of its group, most-overdue group first."""
    acts = [("submit", 0.0, 1, None), ("submit", 0.0, 2, None),
            ("submit", 0.0, 2, 0.10),       # fora(2): lapses second
            ("submit", 0.0, 5, 0.05),       # freqca_a: most overdue
            ("cut", 0.2)]
    submitted, cuts = run_case(acts, 8, max_wait_s=1e9, grouped=True)
    # first cut: the most-overdue lapsed request's (adaptive) group
    assert [r.request_id for r in cuts[0].plan.requests] == [3]
    # second: the other lapsed group, its lapsed member promoted
    assert [r.request_id for r in cuts[1].plan.requests] == [1, 2]


def test_deterministic_rare_group_not_starved():
    """A busy group keeps its bucket full; the rare policy's request is
    served as soon as it heads the queue and ages past max_wait."""
    acts = [("submit", 0.0, 5, None)]                 # rare adaptive
    acts += [("submit", 0.0, 2, None)] * 8            # busy fora group
    acts += [("cut", 0.0)]                            # full-bucket cut
    acts += [("submit", 0.0, 2, None)] * 4            # keeps arriving
    acts += [("cut", 0.2)]                            # rare head aged
    submitted, cuts = run_case(acts, 4, max_wait_s=0.1, grouped=True)
    # cut 1 at t=0: fora bucket full, rare head still young -> fora
    assert all(r.request_id != 0 for r in cuts[0].plan.requests)
    # cut 2 at t=0.2: age pressure -> the rare request's own group
    assert [r.request_id for r in cuts[1].plan.requests] == [0]


def test_deterministic_static_families_share_batches():
    """taylorseer(5)/freqca(5) and fora(1)/none key together: one batch
    each, never one per distinct policy object."""
    acts = [("submit", 0.0, 0, None), ("submit", 0.0, 1, None),
            ("submit", 0.0, 3, None), ("submit", 0.0, 4, None),
            ("cut", 0.2)]
    submitted, cuts = run_case(acts, 8, max_wait_s=0.05, grouped=True)
    assert len(cuts) == 2
    assert [r.request_id for r in cuts[0].plan.requests] == [0, 1]
    assert [r.request_id for r in cuts[1].plan.requests] == [2, 3]


# ---------------------------------------------------------------------------
# multi-resolution deterministic twins
# ---------------------------------------------------------------------------

def _multishape_stream_actions():
    """Shapes and policies both cycling, deadlines sprinkled in — the
    mixed-resolution production stream in miniature."""
    acts = []
    for i in range(20):
        acts.append(("submit", 0.01, i % len(POLICIES),
                     0.2 if i % 5 == 4 else None, i % len(SHAPES)))
        if i % 3 == 2:
            acts.append(("cut", 0.05))
    acts.append(("cut", 1.0))
    return acts


@pytest.mark.parametrize("grouped", [False, True])
@pytest.mark.parametrize("max_batch", [1, 3, 4])
def test_deterministic_multishape_stream(grouped, max_batch):
    """Full invariant set — shape purity included — over a stream that
    mixes four shapes with seven policies, in BOTH formation modes
    (shape purity is unconditional, not a grouping feature)."""
    run_case(_multishape_stream_actions(), max_batch, max_wait_s=0.05,
             grouped=grouped)


@pytest.mark.parametrize("grouped", [False, True])
def test_deterministic_shape_purity_same_policy(grouped):
    """Identical policies at two shapes never share a cut: the shape
    key alone forces separate batches."""
    acts = [("submit", 0.0, 2, None, 1), ("submit", 0.0, 2, None, 2),
            ("submit", 0.0, 2, None, 1), ("cut", 0.2)]
    submitted, cuts = run_case(acts, 8, max_wait_s=0.05, grouped=grouped)
    assert len(cuts) == 2
    assert [r.request_id for r in cuts[0].plan.requests] == [0, 2]
    assert [r.request_id for r in cuts[1].plan.requests] == [1]
    assert cuts[0].plan.latent_shape == SHAPES[1][0]
    assert cuts[1].plan.latent_shape == SHAPES[2][0]


def test_deterministic_no_cross_shape_promotion():
    """A lapsed small-shape request is promoted into its own shape's
    next cut — never pulled into the large-shape batch that triggers
    first, and never starved behind it."""
    # same policy everywhere: only the shape key separates the lanes
    acts = [("submit", 0.0, 2, None, 2)] * 0
    acts = [("submit", 0.0, 2, 0.05, 1),    # small shape, tight deadline
            ("submit", 0.0, 2, None, 2),
            ("submit", 0.0, 2, None, 2),
            ("cut", 0.2),                   # deadline lapsed -> shape 1
            ("cut", 0.0)]                   # then the shape-2 backlog
    submitted, cuts = run_case(acts, 8, max_wait_s=1e9, grouped=True)
    assert [r.request_id for r in cuts[0].plan.requests] == [0]
    assert cuts[0].plan.latent_shape == SHAPES[1][0]
    assert [r.request_id for r in cuts[1].plan.requests] == [1, 2]
    assert cuts[1].plan.latent_shape == SHAPES[2][0]


def test_deterministic_partial_shape_declaration():
    """A request declaring only its latent shape resolves to the unique
    ladder entry matching it (scheduler built with a ladder), and cuts
    stay shape-pure."""
    from repro.serving.scheduler import Scheduler as S
    ladder = {SHAPES[1], SHAPES[2]}
    sched = S(max_batch=4, max_wait_s=0.0, clock=lambda: 0.0,
              default_shape=SHAPES[1], allowed_shapes=set(ladder))
    sched.submit(DiffusionRequest(request_id=0, seed=0,
                                  latent_shape=SHAPES[2][0]), now=0.0)
    sched.submit(DiffusionRequest(request_id=1, seed=1), now=0.0)
    plan = sched.form_batch(now=1.0)
    # the partially-declared request completed to the full SHAPES[2]
    # pair and therefore cannot share the default-shape cut
    assert [r.request_id for r in plan.requests] == [0]
    assert plan.latent_shape == SHAPES[2][0]
    assert plan.crf_shape == SHAPES[2][1]
    plan2 = sched.form_batch(now=1.0)
    assert [r.request_id for r in plan2.requests] == [1]
    assert plan2.latent_shape == SHAPES[1][0]
