"""Unit + property tests for the paper's core: frequency decomposition,
Hermite prediction, CRF caching, and the policy state machines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests skip gracefully when hypothesis is absent (CI installs
# it via `pip install -e .[dev]`; the bare tier-1 env may not have it)
# while the deterministic tests below keep running either way; the
# shared "ci" profile and no-hypothesis shim live in hypothesis_compat
from hypothesis_compat import given, st  # noqa: E402

from repro.core import cache as cache_lib
from repro.core import frequency, hermite
from repro.core.cache import CachePolicy

# ---------------------------------------------------------------------------
# frequency decomposition
# ---------------------------------------------------------------------------

@given(st.sampled_from(["fft", "dct"]),
       st.integers(min_value=4, max_value=64),
       st.floats(min_value=0.02, max_value=0.9),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_band_partition(method, s, rho, seed):
    """low + high == z exactly (the split is a partition) — paper eq. 1."""
    z = jax.random.normal(jax.random.key(seed), (2, s, 8))
    b = frequency.decompose(z, rho, method)
    np.testing.assert_allclose(np.asarray(b.low + b.high), np.asarray(z),
                               atol=1e-5)


@given(st.sampled_from(["fft", "dct"]),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_band_orthogonality(method, seed):
    """Low/high bands are orthogonal (Parseval: energies add up)."""
    z = jax.random.normal(jax.random.key(seed), (1, 32, 4))
    b = frequency.decompose(z, 0.25, method)
    e_low = float(jnp.sum(b.low.astype(jnp.float32) ** 2))
    e_high = float(jnp.sum(b.high.astype(jnp.float32) ** 2))
    e_tot = float(jnp.sum(z.astype(jnp.float32) ** 2))
    assert abs(e_low + e_high - e_tot) / e_tot < 1e-4


def test_constant_signal_is_all_low():
    z = jnp.ones((1, 32, 4)) * 3.0
    for method in ("fft", "dct"):
        b = frequency.decompose(z, 0.1, method)
        assert float(jnp.abs(b.high).max()) < 1e-5, method


def test_nyquist_signal_is_all_high():
    s = 32
    alt = jnp.tile(jnp.array([1.0, -1.0]), s // 2)[None, :, None]
    # FFT: the alternating signal is exactly the Nyquist bin -> zero low
    b = frequency.decompose(alt, 0.1, "fft")
    assert float(jnp.abs(b.low).max()) < 1e-4
    # DCT-II: it is *almost* the top basis vector (phase taper leaks a
    # little); low-band energy must still be tiny
    b = frequency.decompose(alt, 0.1, "dct")
    e_low = float(jnp.sum(b.low ** 2))
    e_tot = float(jnp.sum(alt ** 2))
    assert e_low / e_tot < 0.02


def test_low_pass_band_width_consistency():
    """FFT and DCT decompose the same band for the same rho: kept-bin
    counts agree within one bin (the FFT's conjugate-symmetry rounding —
    DC + whole ± pairs — rounds an even target up, never down).
    Regression: rho=0.5, n=8 used to keep 4 DCT bins but only 3 FFT
    bins, so the two methods split different bands."""
    for n, rho in [(8, 0.5), (16, 0.25), (32, 0.1), (64, 0.0625),
                   (7, 0.5), (8, 1.0)]:
        kept = {}
        for method in ("fft", "dct"):
            mask = frequency.low_pass_mask(n, rho, method)
            kept[method] = int(jnp.sum(mask))
            assert kept[method] == frequency.kept_bins(n, rho, method)
        m = min(max(int(round(n * rho)), 1), n)
        assert kept["dct"] == m
        assert abs(kept["fft"] - kept["dct"]) <= 1
        assert kept["fft"] >= kept["dct"]     # rounds up, never narrower


@given(st.integers(min_value=2, max_value=128),
       st.floats(min_value=0.01, max_value=1.0))
def test_low_pass_kept_fraction_agrees(n, rho):
    """Property: for any (n, rho) the FFT and DCT masks keep the same
    fraction of the spectrum within one bin."""
    kd = int(jnp.sum(frequency.low_pass_mask(n, rho, "dct")))
    kf = int(jnp.sum(frequency.low_pass_mask(n, rho, "fft")))
    assert kd == min(max(int(round(n * rho)), 1), n)
    assert abs(kf - kd) <= 1
    assert kd <= kf <= n


def test_low_band_basis_factorises_projection():
    """B: [m, n] orthonormal rows with L = BᵀB — the spectral cache
    representation spans exactly the masked-transform low band."""
    for method in ("fft", "dct"):
        for n, rho in [(16, 0.25), (64, 0.0625), (8, 0.5), (8, 1.0),
                       (7, 0.5)]:
            b = np.asarray(frequency._low_band_basis_np(n, rho, method))
            assert b.shape == (frequency.spectral_kept_bins(n, rho,
                                                            method), n)
            np.testing.assert_allclose(b @ b.T, np.eye(b.shape[0]),
                                       atol=1e-10)
            z = np.asarray(jax.random.normal(jax.random.key(3), (2, n, 4)))
            low = np.einsum("ms,bsd->bmd", b, z)
            recon = np.einsum("ms,bmd->bsd", b, low)
            bands = frequency.decompose(jnp.asarray(z), rho, method)
            np.testing.assert_allclose(recon, np.asarray(bands.low),
                                       atol=1e-5)
    # method="none": an all-zero basis row — empty low band, static shape
    b = np.asarray(frequency._low_band_basis_np(16, 0.25, "none"))
    assert b.shape == (1, 16) and not b.any()
    assert frequency.spectral_kept_bins(16, 0.25, "none") == 1


def test_decompose_idempotent():
    """Low band of the low band is the low band (projection)."""
    z = jax.random.normal(jax.random.key(0), (1, 64, 8))
    b = frequency.decompose(z, 0.25, "dct")
    b2 = frequency.decompose(b.low, 0.25, "dct")
    np.testing.assert_allclose(np.asarray(b2.low), np.asarray(b.low),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Hermite predictor
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2),
       st.floats(min_value=-2, max_value=2),
       st.floats(min_value=-2, max_value=2),
       st.floats(min_value=-2, max_value=2))
def test_hermite_exact_on_polynomials(order, c0, c1, c2):
    """With K = order+1 points the fit reproduces any degree<=order poly."""
    coeffs = [c0, c1, c2][: order + 1]

    def poly(t):
        return sum(c * t ** i for i, c in enumerate(coeffs))

    ts = jnp.array([1.0, 0.8, 0.6][: order + 1])
    vals = jnp.stack([jnp.full((3, 3), poly(float(t))) for t in ts])
    pred = hermite.predict(ts, vals, 0.4, order=order)
    np.testing.assert_allclose(np.asarray(pred), poly(0.4), atol=5e-3)


def test_hermite_basis_recurrence():
    s = jnp.linspace(-1, 1, 7)
    b = hermite.hermite_basis(s, 3)
    np.testing.assert_allclose(np.asarray(b[:, 0]), 1.0)
    np.testing.assert_allclose(np.asarray(b[:, 1]), np.asarray(s), atol=1e-6)
    np.testing.assert_allclose(np.asarray(b[:, 2]), np.asarray(s * s - 1),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(b[:, 3]),
                               np.asarray(s ** 3 - 3 * s), atol=1e-5)


def test_hermite_interpolates_samples():
    """Evaluating at a cached timestamp returns the cached value."""
    ts = jnp.array([0.9, 0.6, 0.3])
    vals = jax.random.normal(jax.random.key(0), (3, 4, 4))
    for i, t in enumerate([0.9, 0.6, 0.3]):
        pred = hermite.predict(ts, vals, t, order=2)
        np.testing.assert_allclose(np.asarray(pred), np.asarray(vals[i]),
                                   atol=2e-3)


# ---------------------------------------------------------------------------
# cache policies
# ---------------------------------------------------------------------------

def _fill(policy, shape, traj, ts):
    st_ = cache_lib.init_state(policy, shape)
    for t in ts:
        st_ = cache_lib.update(policy, st_, traj(t), t)
    return st_


def test_fora_reuses_last():
    pol = CachePolicy(kind="fora", interval=3)
    shape = (1, 8, 4)
    traj = lambda t: jnp.full(shape, t)
    st_ = _fill(pol, shape, traj, [1.0, 0.8])
    np.testing.assert_allclose(np.asarray(cache_lib.predict(pol, st_, 0.6)),
                               0.8, atol=1e-6)


def test_taylorseer_extrapolates_quadratic():
    pol = CachePolicy(kind="taylorseer", interval=3, high_order=2)
    shape = (1, 8, 4)
    traj = lambda t: jnp.full(shape, 1.0 + 2 * t - t * t)
    st_ = _fill(pol, shape, traj, [1.0, 0.8, 0.6])
    want = 1.0 + 2 * 0.4 - 0.16
    np.testing.assert_allclose(np.asarray(cache_lib.predict(pol, st_, 0.4)),
                               want, atol=1e-3)


def test_freqca_separates_bands():
    """Low band (constant) reused; high band (alternating) predicted."""
    s = 16
    pol = CachePolicy(kind="freqca", interval=3, method="dct", rho=0.25,
                      high_order=2)
    alt = jnp.tile(jnp.array([1.0, -1.0]), s // 2)[None, :, None]
    alt = jnp.broadcast_to(alt, (1, s, 4))

    def traj(t):  # low: const 5t ; high: alternating with quadratic scale
        return jnp.full((1, s, 4), 5.0 * t) + alt * (t * t)

    st_ = _fill(pol, (1, s, 4), traj, [1.0, 0.8, 0.6])
    pred = cache_lib.predict(pol, st_, 0.4)
    # low-frequency part should be the REUSED value 5*0.6 = 3.0 …
    mean_part = float(jnp.mean(pred))
    assert abs(mean_part - 3.0) < 1e-2
    # … while the high band extrapolates t^2 -> 0.16
    high_amp = float(jnp.mean(pred * alt))
    assert abs(high_amp - 0.16) < 2e-2


def test_should_activate_schedule_and_warmup():
    pol = CachePolicy(kind="freqca", interval=4, high_order=2)
    st_ = cache_lib.init_state(pol, (1, 4, 4))
    # no history yet -> always activate (warmup)
    assert bool(cache_lib.should_activate(pol, st_, jnp.asarray(1)))
    for t in [1.0, 0.9, 0.8]:
        st_ = cache_lib.update(pol, st_, jnp.zeros((1, 4, 4)), t)
    assert not bool(cache_lib.should_activate(pol, st_, jnp.asarray(1)))
    assert bool(cache_lib.should_activate(pol, st_, jnp.asarray(4)))


def test_cache_units_match_paper():
    """Paper §4.4.1: FreqCa = 1 + 3 = 4 units; layer-wise = 2(m+1)L."""
    pol = CachePolicy(kind="freqca", low_order=0, high_order=2)
    assert pol.cache_units == 4
    assert CachePolicy(kind="fora").cache_units == 1
    assert CachePolicy(kind="taylorseer", high_order=2).cache_units == 3


def test_cache_bytes_o1_vs_layerwise():
    feat = (2, 64, 32)
    pol = CachePolicy(kind="freqca", high_order=2)
    crf_state = cache_lib.init_state(pol, feat)
    lw_state = cache_lib.layerwise_init(pol, n_layers=57, feat_shape=feat)
    crf_b = cache_lib.cache_bytes(crf_state)
    lw_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(lw_state))
    # paper: ~99% memory reduction vs layer-wise caching
    assert crf_b < 0.03 * lw_b
