"""Runtime sanitizer tests (``repro.analysis.runtime``).

The lock-order sanitizer must catch a seeded inversion *as an error*
(not a deadlock), stay silent on consistent orders, and keep
``threading.Condition`` semantics intact (the serving stack's cv.wait
path runs through ``_release_save``/``_acquire_restore``).  With
sanitizing off the factories return plain threading primitives — the
default costs nothing.

The tracer-leak sanitizer must spot a ``jax.core.Tracer`` smuggled out
of a trace into host-side containers, and accept ordinary pytrees.
"""
import dataclasses
import threading

import pytest

from repro.analysis import runtime as rt


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    rt.reset_order_graph()
    yield
    rt.reset_order_graph()


# ---------------------------------------------------------------------------
# factories: plain primitives unless REPRO_SANITIZE=1
# ---------------------------------------------------------------------------

def test_factories_are_plain_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert isinstance(rt.make_lock("x"), type(threading.Lock()))
    assert isinstance(rt.make_condition("x"), threading.Condition)
    assert not rt.enabled()


def test_enabled_reads_env_at_call_time(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not rt.enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert rt.enabled()   # no import-frozen state


# ---------------------------------------------------------------------------
# lock-order sanitizer
# ---------------------------------------------------------------------------

def test_seeded_inversion_raises_not_deadlocks(sanitized):
    a = rt.make_lock("A")
    b = rt.make_lock("B")
    with a:
        with b:
            pass                      # records A -> B
    with b:
        with pytest.raises(rt.LockOrderError, match="inversion"):
            with a:                   # B -> A closes the cycle
                pass
    # the refused acquire never entered: both locks are free again
    assert a.acquire(blocking=False)
    a.release()
    assert b.acquire(blocking=False)
    b.release()


def test_consistent_order_is_silent(sanitized):
    a = rt.make_lock("A")
    b = rt.make_lock("B")
    c = rt.make_lock("C")
    for _ in range(3):
        with a:
            with b:
                with c:
                    pass
    assert rt.order_graph() == {"A": {"B", "C"}, "B": {"C"}}


def test_inversion_detected_across_threads(sanitized):
    # thread 1 takes A then B; the main thread then tries B then A —
    # with real threads this interleaving is a timing-dependent
    # deadlock, with the sanitizer it's a deterministic error
    a = rt.make_lock("A")
    b = rt.make_lock("B")

    def t1():
        with a:
            with b:
                pass

    th = threading.Thread(target=t1)
    th.start()
    th.join()
    with b:
        with pytest.raises(rt.LockOrderError):
            a.acquire()


def test_rlock_reentrancy_is_not_an_edge(sanitized):
    r = rt.make_rlock("R")
    with r:
        with r:                       # reentrant: no self-edge, no error
            pass
    assert rt.order_graph() == {}


def test_condition_wait_notify_through_sanitized_lock(sanitized):
    cv = rt.make_condition("CV")
    hits = []

    def waiter():
        with cv:
            while not hits:
                cv.wait(timeout=5.0)
            hits.append("woke")

    th = threading.Thread(target=waiter)
    th.start()
    # wait() fully releases the sanitized lock, so the notifier can
    # acquire it — this exercises _release_save/_acquire_restore
    with cv:
        hits.append("sent")
        cv.notify_all()
    th.join(timeout=5.0)
    assert not th.is_alive()
    assert hits == ["sent", "woke"]


def test_condition_over_shared_lock_is_one_node(sanitized):
    # the FleetRouter shape: _cv wraps _lock; using both nested must
    # not look like two locks (no edge, no inversion)
    lk = rt.make_lock("R._lock")
    cv = rt.make_condition("R._cv", lock=lk)
    with cv:
        pass
    with lk:
        pass
    assert rt.order_graph() == {}


def test_scheduler_cv_is_sanitized_under_flag(sanitized):
    from repro.serving.scheduler import Scheduler
    sched = Scheduler(max_batch=2)
    assert isinstance(sched.cv._lock, rt._TrackedLock)
    # and the cv still works as a condition variable
    with sched.cv:
        sched.cv.notify_all()


# ---------------------------------------------------------------------------
# tracer-leak sanitizer
# ---------------------------------------------------------------------------

def test_tracer_leak_detected():
    jax = pytest.importorskip("jax")
    leak = []

    @jax.jit
    def f(x):
        leak.append(x)               # the classic escape
        return x * 2

    f(1.0)
    with pytest.raises(rt.TracerLeakError, match="leaked"):
        rt.check_tracer_leaks({"stash": leak}, "policy state")


def test_tracer_leak_walks_dataclasses_and_ignores_clean_values():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    @dataclasses.dataclass(frozen=True)
    class Sig:
        name: str
        ring: tuple

    clean = Sig("freqca", (jnp.zeros(3), [1, 2], {"k": "v"}))
    rt.check_tracer_leaks(clean, "signature")   # no raise

    leak = []

    @jax.jit
    def f(x):
        leak.append(x)
        return x

    f(jnp.ones(2))
    dirty = Sig("freqca", (leak[0],))
    with pytest.raises(rt.TracerLeakError):
        rt.check_tracer_leaks(dirty, "signature")


def test_tracer_leak_handles_self_referential_containers():
    d = {}
    d["loop"] = d                     # must not recurse forever
    rt.check_tracer_leaks(d, "state")
