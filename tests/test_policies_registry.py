"""Policy-object registry tests: golden equivalence against the legacy
string-`kind` sampler path, per-lane isolation in mixed batches,
derived warm-up lengths, the FoCa extension, policy-aware cache-bytes
accounting, and the open-loop Poisson arrival plan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.core import cache as cache_lib
from repro.core import policies
from repro.core.cache import CachePolicy
from repro.core.policies import base as policy_base
from repro.diffusion import sampler, schedule
from repro.models import common, dit


@pytest.fixture(scope="module")
def tiny_dit():
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, 8, 8)

    x0 = jax.random.normal(jax.random.key(1), (2, 8, 8, cfg.in_channels))
    return cfg, full_fn, from_crf_fn, x0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_policy_family():
    names = policies.available()
    for expected in ("freqca", "freqca_a", "taylorseer", "fora",
                     "teacache", "none", "foca"):
        assert expected in names


def test_resolve_spec_and_passthrough():
    spec = CachePolicy(kind="freqca", interval=7, rho=0.25, high_order=3)
    pol = policies.resolve(spec)
    assert isinstance(pol, policies.FreqCaPolicy)
    assert (pol.interval, pol.rho, pol.high_order) == (7, 0.25, 3)
    assert policies.resolve(pol) is pol            # objects pass through
    assert policies.resolve(spec) == pol           # value-equal -> same key
    with pytest.raises(KeyError):
        policies.resolve(CachePolicy(kind="no-such-policy"))
    with pytest.raises(TypeError):
        policies.resolve(42)


def test_policy_metadata_matches_spec():
    resolve = policies.resolve
    assert resolve(CachePolicy(kind="freqca")).cache_units == 4
    assert resolve(CachePolicy(kind="fora")).cache_units == 1
    assert resolve(CachePolicy(kind="taylorseer")).cache_units == 3
    assert resolve(CachePolicy(kind="none")).cache_units == 0
    # warm-up length is derived from the predictor's history needs
    assert resolve(CachePolicy(kind="freqca_a")).needed_history == 3
    assert resolve(CachePolicy(kind="freqca_a",
                               high_order=4)).needed_history == 5


def test_compatibility_keys():
    """Batch-compatibility grouping: static-schedule policies key by the
    activation schedule they produce (so mask-identical families share),
    adaptive policies key by full value (data-dependent masks only share
    with the identical policy)."""
    key = policies.compatibility_key
    # identical resolved policies -> identical keys, spec or object
    assert key(CachePolicy(kind="freqca", interval=5)) == \
        key(policies.resolve(CachePolicy(kind="freqca", interval=5)))
    # same (interval, needed_history) static schedule -> one family,
    # across different predictors
    assert key(CachePolicy(kind="freqca", interval=5)) == \
        key(CachePolicy(kind="taylorseer", interval=5))
    assert key(CachePolicy(kind="fora", interval=1)) == \
        key(CachePolicy(kind="none"))
    # schedule differences split the family
    assert key(CachePolicy(kind="fora", interval=2)) != \
        key(CachePolicy(kind="fora", interval=3))
    assert key(CachePolicy(kind="fora", interval=5)) != \
        key(CachePolicy(kind="freqca", interval=5))   # warmup differs
    # adaptive policies: value-keyed, never share with static schedules
    a1 = CachePolicy(kind="freqca_a", tea_threshold=0.3)
    a2 = CachePolicy(kind="freqca_a", tea_threshold=0.2)
    assert key(a1) == key(a1) != key(a2)
    assert key(a1) != key(CachePolicy(kind="freqca"))
    assert key(CachePolicy(kind="teacache")) != key(a1)
    # banks expose the key too: uniform -> the policy's, mixed ->
    # collapsed when every lane is compatible
    assert policies.bank(a1, 2).compatibility_key() == key(a1)
    fam = policies.bank([CachePolicy(kind="fora", interval=1),
                         CachePolicy(kind="none")], 2)
    assert fam.compatibility_key() == key(CachePolicy(kind="none"))
    mixed = policies.bank([a1, CachePolicy(kind="none")], 2)
    assert mixed.compatibility_key() == (key(a1),
                                         key(CachePolicy(kind="none")))


# ---------------------------------------------------------------------------
# golden equivalence vs the legacy string-`kind` sampler
# ---------------------------------------------------------------------------

def _legacy_sample(full_fn, from_crf_fn, x_init, ts, policy, crf_shape,
                   crf_dtype=jnp.float32):
    """Verbatim port of the seed sampler (string-`kind` dispatch +
    sampler-resident tea0 carries) — the golden reference."""
    n_steps = ts.shape[0] - 1
    state0 = cache_lib.init_state(policy, crf_shape, crf_dtype)
    tea0 = (jnp.zeros((), jnp.float32), jnp.zeros_like(x_init),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))

    def step(carry, inp):
        x, state, tea = carry
        i, t_now, t_next = inp
        acc, prev_x, since, err_last = tea

        def full_branch(op):
            x_, state_ = op
            v, crf = full_fn(x_, t_now)
            if policy.kind == "freqca_a":
                pred = cache_lib.predict(policy, state_, t_now)
                err = jnp.linalg.norm(
                    (pred - crf).astype(jnp.float32)) / jnp.maximum(
                    jnp.linalg.norm(crf.astype(jnp.float32)), 1e-6)
            else:
                err = jnp.zeros((), jnp.float32)
            return v, cache_lib.update(policy, state_, crf, t_now), 1, err

        def cached_branch(op):
            x_, state_ = op
            crf_hat = cache_lib.predict(policy, state_, t_now)
            return (from_crf_fn(crf_hat, t_now), state_, 0,
                    jnp.zeros((), jnp.float32))

        if policy.kind == "teacache":
            rel = jnp.mean(jnp.abs(x - prev_x)) / jnp.maximum(
                jnp.mean(jnp.abs(prev_x)), 1e-6)
            acc = acc + rel.astype(jnp.float32)
            warm = state.n_valid < 1
            act = warm | (acc > policy.tea_threshold) | (i == 0)
            acc = jnp.where(act, 0.0, acc)
        elif policy.kind == "freqca_a":
            warm = state.n_valid < 3
            projected = (since.astype(jnp.float32) + 1.0) * err_last
            act = warm | (projected > policy.tea_threshold)
        else:
            act = cache_lib.should_activate(policy, state, i)
        if policy.kind == "none":
            v, state, used, err_new = full_branch((x, state))
        else:
            v, state, used, err_new = jax.lax.cond(
                act, full_branch, cached_branch, (x, state))
        since = jnp.where(jnp.asarray(used, bool), 0, since + 1)
        err_last = jnp.where(jnp.asarray(used, bool), err_new, err_last)
        dt = (t_next - t_now).astype(x.dtype)
        x_new = x + dt * v.astype(x.dtype)
        return (x_new, state, (acc, x, since, err_last)), \
            jnp.asarray(used, jnp.int32)

    idx = jnp.arange(n_steps)
    (x, _, _), used = jax.lax.scan(step, (x_init, state0, tea0),
                                   (idx, ts[:-1], ts[1:]))
    return x, jnp.sum(used)


SEED_CONFIGS = [
    CachePolicy(kind="none"),
    CachePolicy(kind="fora", interval=5),
    CachePolicy(kind="taylorseer", interval=5, high_order=2),
    CachePolicy(kind="freqca", interval=5, method="dct", rho=0.25),
    CachePolicy(kind="freqca", interval=3, method="fft", rho=0.0625),
    CachePolicy(kind="freqca", interval=5, method="none"),
]


def _assert_golden(pol, got, want):
    """FreqCa's low band is now cached spectrally: mathematically the
    same projection as the legacy spatial cache, but a different matmul
    association — float tolerance for dct/fft.  ``method="none"`` (zero
    low band) and every non-decomposing policy stay BITWISE equal."""
    if pol.kind.startswith("freqca") and pol.method != "none":
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pol", SEED_CONFIGS,
                         ids=lambda p: f"{p.kind}-{p.method}-{p.interval}")
def test_golden_equivalence_scheduled(tiny_dit, pol):
    """Registered policy objects match the legacy spatial-cache path on
    the seed configs (scheduled policies, batch > 1) — bitwise except
    for the spectral freqca low band (see _assert_golden)."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    crf_shape = (2, 16, cfg.d_model)
    want_x, want_full = _legacy_sample(full_fn, from_crf_fn, x0, ts, pol,
                                       crf_shape)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=crf_shape)
    _assert_golden(pol, res.x, want_x)
    assert int(res.n_full) == int(want_full)
    np.testing.assert_array_equal(np.asarray(res.n_full_lanes),
                                  int(want_full))


@pytest.mark.parametrize("pol", [
    CachePolicy(kind="teacache", tea_threshold=0.05),
    CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25),
], ids=lambda p: p.kind)
def test_golden_equivalence_adaptive_solo(tiny_dit, pol):
    """Adaptive policies match the legacy path at batch 1, where the
    legacy batch-global decision IS the lane decision.  (At batch > 1
    the new path is per-lane by design — covered below.)"""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    x0 = x0[:1]
    crf_shape = (1, 16, cfg.d_model)
    want_x, want_full = _legacy_sample(full_fn, from_crf_fn, x0, ts, pol,
                                       crf_shape)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=crf_shape)
    _assert_golden(pol, res.x, want_x)
    assert int(res.n_full_lanes[0]) == int(want_full)


# ---------------------------------------------------------------------------
# per-lane isolation
# ---------------------------------------------------------------------------

def test_mixed_batch_lane_matches_solo(tiny_dit):
    """A lane keeps its solo-batch behaviour inside a mixed-policy
    batch: the `none` lane matches its solo uncached run, the cached
    lane matches its solo cached run, and per-lane n_full decouple."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(16)
    mix = (CachePolicy(kind="none"),
           CachePolicy(kind="freqca", interval=4, rho=0.25))
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, mix,
                         crf_shape=(2, 16, cfg.d_model))
    assert int(res.n_full_lanes[0]) == 16
    assert int(res.n_full_lanes[1]) < 16
    assert int(res.n_full) == 16        # forwards = union of activations
    for j, pol in enumerate(mix):
        solo = sampler.sample(full_fn, from_crf_fn, x0[j:j + 1], ts, pol,
                              crf_shape=(1, 16, cfg.d_model))
        assert int(solo.n_full_lanes[0]) == int(res.n_full_lanes[j])
        np.testing.assert_allclose(np.asarray(res.x[j]),
                                   np.asarray(solo.x[0]), atol=1e-5)


def test_uniform_adaptive_batch_is_per_lane(tiny_dit):
    """A single adaptive policy over a batch now decides per lane: each
    lane matches its solo run even when the other lane's content would
    have flipped the old batch-global decision."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    pol = CachePolicy(kind="freqca_a", tea_threshold=0.3, rho=0.25)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=(2, 16, cfg.d_model))
    for j in range(2):
        solo = sampler.sample(full_fn, from_crf_fn, x0[j:j + 1], ts, pol,
                              crf_shape=(1, 16, cfg.d_model))
        assert int(solo.n_full_lanes[0]) == int(res.n_full_lanes[j])
        np.testing.assert_allclose(np.asarray(res.x[j]),
                                   np.asarray(solo.x[0]), atol=1e-5)


# ---------------------------------------------------------------------------
# derived warm-up (satellite: no hard-coded `n_valid < 3`)
# ---------------------------------------------------------------------------

def test_freqca_a_warmup_follows_high_order(tiny_dit):
    """With an unbounded error budget freqca_a activates exactly its
    warm-up steps — which must track `high_order`, not the old
    hard-coded 3, so a bigger ring is never sampled underfilled."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    for high_order, want in [(2, 3), (4, 5)]:
        pol = CachePolicy(kind="freqca_a", tea_threshold=1e9,
                          high_order=high_order, rho=0.25)
        res = sampler.sample(full_fn, from_crf_fn, x0[:1], ts, pol,
                             crf_shape=(1, 16, cfg.d_model))
        assert int(res.n_full_lanes[0]) == want, (high_order, want)


# ---------------------------------------------------------------------------
# FoCa (registry extensibility)
# ---------------------------------------------------------------------------

def _ctx(t, batch=1, feat_shape=(4,)):
    return policy_base.StepContext(
        step_idx=jnp.asarray(0), t_now=jnp.asarray(t),
        x=jnp.zeros((batch, 1)), batch=batch, feat_shape=feat_shape)


def test_foca_calibrated_forecast():
    """FoCa = TaylorSeer forecast + per-lane gain calibration: exact on
    a linear trajectory (gain 1), gain-corrected under uniform drift."""
    pol = policies.FoCaPolicy(interval=3, high_order=1)
    traj = lambda t: jnp.full((1, 4), 2.0 - t)
    state = pol.init(1, (4,))
    for t in [1.0, 0.8, 0.6]:
        state = pol.update(state, traj(t), _ctx(t))
    np.testing.assert_allclose(np.asarray(state.gain), 1.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pol.predict(state, _ctx(0.4))),
                               1.6, atol=1e-3)
    # trajectory jumps to 1.5x the forecast -> gain refits toward 1.5
    state = pol.update(state, 1.5 * traj(0.4), _ctx(0.4))
    assert abs(float(state.gain[0]) - 1.5) < 0.01
    # ... and is clipped to calib_clip under extreme drift
    state = pol.update(state, 100.0 * traj(0.2), _ctx(0.2))
    assert float(state.gain[0]) == pytest.approx(pol.calib_clip)


def test_foca_samples_end_to_end(tiny_dit):
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(20)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts,
                         CachePolicy(kind="foca", interval=5),
                         crf_shape=(2, 16, cfg.d_model))
    assert bool(jnp.isfinite(res.x).all())
    assert int(res.n_full) < 20


# ---------------------------------------------------------------------------
# cache-bytes accounting (satellite: dummy slots excluded)
# ---------------------------------------------------------------------------

def test_cache_bytes_excludes_dummy_low_slot():
    feat = (1, 32, 16)
    for kind in ("taylorseer", "foca", "fora", "teacache"):
        pol = CachePolicy(kind=kind, high_order=2)
        state = cache_lib.init_state(pol, feat)
        raw = cache_lib.cache_bytes(state)
        real = cache_lib.cache_bytes(state, pol)
        dummy = (state.low_hist.size * state.low_hist.dtype.itemsize
                 + state.ts_low.size * state.ts_low.dtype.itemsize)
        assert real == raw - dummy, kind
        # memory scales with cache_units, matching §4.4.1 accounting
        per_unit = (state.high_hist.size // pol.cache_units
                    * state.high_hist.dtype.itemsize)
        assert real >= per_unit * pol.cache_units, kind
    pol = CachePolicy(kind="none")
    assert cache_lib.cache_bytes(cache_lib.init_state(pol, feat), pol) == 0
    # freqca uses both bands: nothing excluded
    pol = CachePolicy(kind="freqca")
    state = cache_lib.init_state(pol, feat)
    assert cache_lib.cache_bytes(state, pol) == cache_lib.cache_bytes(state)
    # the new policy objects carry no dummy slots at all
    obj = policies.resolve(CachePolicy(kind="taylorseer", high_order=2))
    st = obj.init(1, feat)
    want = (np.prod((1, 3) + feat) * 4      # hist [B, K, *feat] f32
            + 3 * 4                          # ts [B, K]
            + 4                              # head [B] int32 (slot ptr)
            + 4)                             # n_valid [B] int32
    assert obj.state_bytes(st) == want


# ---------------------------------------------------------------------------
# slot-pointer ring (satellite: ring_push touches one slot, not the ring)
# ---------------------------------------------------------------------------

def _roll_push(vals, ts, v, t):
    """The old O(K·S·D) roll implementation — the regression oracle."""
    vals = jnp.roll(vals, -1, axis=1).at[:, -1].set(v)
    ts = jnp.roll(ts, -1, axis=1).at[:, -1].set(t)
    return vals, ts


def test_ring_pointer_matches_roll():
    """Pointer ring == roll ring through >K pushes (head wraps): the
    recency-ordered view, ring_last, and ring_predict are bit-equal."""
    from repro.core import hermite
    k, batch, feat = 3, 2, (4, 5)
    ring = policy_base.ring_init(batch, k, feat)
    rvals, rts = ring.vals, ring.ts
    rng = jax.random.key(7)
    for t in [1.0, 0.9, 0.8, 0.7, 0.6]:
        rng, sub = jax.random.split(rng)
        v = jax.random.normal(sub, (batch,) + feat)
        ring = policy_base.ring_push(ring, v, t)
        rvals, rts = _roll_push(rvals, rts, v, t)
        ts_o, vals_o = policy_base.ring_ordered(ring)
        np.testing.assert_array_equal(np.asarray(ts_o), np.asarray(rts))
        np.testing.assert_array_equal(np.asarray(vals_o), np.asarray(rvals))
        np.testing.assert_array_equal(
            np.asarray(policy_base.ring_last(ring)),
            np.asarray(rvals[:, -1]))
        want = jax.vmap(
            lambda a, b: hermite.predict(a, b, 0.5, 2))(rts, rvals)
        np.testing.assert_array_equal(
            np.asarray(policy_base.ring_predict(ring, 0.5, 2)),
            np.asarray(want))


def test_ring_slot_weights_permute_fold():
    """Slot-indexed folded weights applied to the raw (cyclic) ring
    reproduce the recency-ordered prediction."""
    k, batch, feat = 4, 2, (8,)
    ring = policy_base.ring_init(batch, k, feat)
    rng = jax.random.key(8)
    for t in [1.0, 0.8, 0.6, 0.5, 0.45, 0.4]:   # head wraps past K
        rng, sub = jax.random.split(rng)
        ring = policy_base.ring_push(
            ring, jax.random.normal(sub, (batch,) + feat), t)
    w = policy_base.ring_slot_weights(ring, 0.3, 2)
    got = jnp.einsum("bk,bk...->b...", w, ring.vals)
    want = policy_base.ring_predict(ring, 0.3, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# spectral low-band cache (tentpole)
# ---------------------------------------------------------------------------

def test_freqca_state_is_spectral_and_small():
    """The low ring holds kept_bins(S, rho) coefficient rows — ≥10x
    smaller than the spatial low ring at the paper's rho (ISSUE
    acceptance), with state_bytes reporting the real footprint."""
    from repro.core import frequency
    s, d, rho = 256, 64, 0.0625
    pol = policies.FreqCaPolicy(interval=5, method="dct", rho=rho)
    state = pol.init(2, (s, d))
    m = frequency.kept_bins(s, rho, "dct")
    assert state.low.vals.shape == (2, pol.k_low, m, d)
    assert state.high.vals.shape == (2, pol.k_high, s, d)
    low_bytes = sum(x.size * x.dtype.itemsize for x in state.low)
    spatial_low_bytes = 2 * pol.k_low * s * d * 4
    assert low_bytes * 10 <= spatial_low_bytes, (low_bytes,
                                                 spatial_low_bytes)
    assert pol.state_bytes(state) < (2 * (pol.k_low + pol.k_high)
                                     * s * d * 4)
    # freqca_a shares the spectral layout
    pol_a = policies.resolve(CachePolicy(kind="freqca_a", rho=rho))
    st_a = pol_a.init(1, (s, d))
    assert st_a.low.vals.shape == (1, pol_a.k_low, m, d)


def test_spectral_predict_reconstructs_low_band():
    """update→predict round-trip: with a full ring, prediction equals
    synthesised low + Hermite high — and, for a band-limited constant
    trajectory, exactly the cached signal."""
    from repro.core import frequency
    s, d = 32, 8
    pol = policies.FreqCaPolicy(interval=5, method="dct", rho=0.25,
                                high_order=2)
    z = frequency.decompose(
        jax.random.normal(jax.random.key(9), (1, s, d)), 0.25, "dct").low
    state = pol.init(1, (s, d))
    for t in [1.0, 0.8, 0.6]:
        state = pol.update(state, z, _ctx(t, feat_shape=(s, d)))
    pred = pol.predict(state, _ctx(0.4, feat_shape=(s, d)))
    np.testing.assert_allclose(np.asarray(pred), np.asarray(z), atol=1e-3)


@pytest.mark.pallas
def test_sampler_pallas_dispatch_matches_xla(tiny_dit, monkeypatch):
    """Full sample() under REPRO_KERNELS=pallas (interpret) matches the
    XLA dispatch path — the CI guard that keeps the kernel-backed cache
    datapath from rotting."""
    cfg, full_fn, from_crf_fn, x0 = tiny_dit
    ts = schedule.timesteps(12)
    pol = CachePolicy(kind="freqca", interval=4, method="dct", rho=0.25)
    crf_shape = (2, 16, cfg.d_model)
    monkeypatch.setenv("REPRO_KERNELS", "xla")
    want = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                          crf_shape=crf_shape)
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    got = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                         crf_shape=crf_shape)
    assert int(got.n_full) == int(want.n_full)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# Poisson arrival plan (satellite: open-loop client)
# ---------------------------------------------------------------------------

def test_poisson_stream_plan():
    from repro.launch.serve import poisson_stream
    plan = poisson_stream(200, rate=4.0, size=8, channels=4,
                          edit_every=5, seed=3)
    times = [r.arrival_s for r in plan]   # unified request API: the
    assert len(plan) == 200               # request carries its arrival
    assert all(b > a for a, b in zip(times, times[1:], strict=False))
    gaps = np.diff([0.0] + times)
    assert abs(float(np.mean(gaps)) - 0.25) < 0.06    # mean ~ 1/rate
    # deterministic for a fixed seed; different seed -> different plan
    again = poisson_stream(200, rate=4.0, size=8, channels=4,
                           edit_every=5, seed=3)
    assert [r.arrival_s for r in again] == times
    other = poisson_stream(200, rate=4.0, size=8, channels=4,
                           edit_every=5, seed=4)
    assert [r.arrival_s for r in other] != times
    # editing requests keep their cadence inside the plan
    assert all(plan[i].init_latents is not None
               for i in range(4, 200, 5))
    with pytest.raises(ValueError):
        poisson_stream(4, rate=0.0, size=8, channels=4)
