"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED
variant of the same family (2 layers / 8 for hybrid, d_model=128, <=4
experts) and run one forward AND one train step on CPU, asserting output
shapes and finiteness.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as config_lib
from repro.launch import steps as steps_lib
from repro.models import common, dit, encdec, transformer
from repro.optim import adamw

BATCH, SEQ = 2, 32


def _batch_for(cfg):
    b = {"tokens": jax.random.randint(jax.random.key(0), (BATCH, SEQ), 0,
                                      cfg.vocab_size)}
    b["labels"] = jnp.concatenate(
        [b["tokens"][:, 1:], -jnp.ones((BATCH, 1), jnp.int32)], axis=1)
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(jax.random.key(1),
                                        (BATCH, SEQ, cfg.d_model)) * 0.1
        b["labels"] = jnp.concatenate(
            [b["tokens"][:, 1:], -jnp.ones((BATCH, 1), jnp.int32)], axis=1)
    if cfg.n_prefix_tokens > 0:
        b["prefix_embeds"] = jax.random.normal(
            jax.random.key(2), (BATCH, cfg.n_prefix_tokens, cfg.d_model)) * .1
    return b


@pytest.mark.parametrize("arch", config_lib.ASSIGNED)
def test_reduced_forward_and_train_step(arch):
    cfg = config_lib.reduced(config_lib.get_config(arch))
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    specs = steps_lib.model_specs(cfg)
    params = common.init_params(specs, jax.random.key(0))
    batch = _batch_for(cfg)

    # forward: shapes + finiteness
    if cfg.is_encdec:
        out = encdec.forward(params, batch["frames"], batch["tokens"], cfg)
        logits, crf = out.logits, out.crf
        assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    else:
        out = transformer.forward(
            params, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds"))
        logits, crf = out.logits, out.crf
        total = SEQ + cfg.n_prefix_tokens
        assert logits.shape == (BATCH, total, cfg.vocab_size)
        assert crf.shape == (BATCH, total, cfg.d_model)
    assert bool(jnp.isfinite(logits).all()), arch

    # one train step: loss finite and params update
    fn, opt_cfg = steps_lib.make_train_step(cfg)
    opt_state = adamw.init(opt_cfg, params)
    new_params, new_opt, metrics = jax.jit(fn)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    # at least one leaf changed
    changed = any(
        not jnp.allclose(a, b)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params), strict=True))
    assert changed, arch


@pytest.mark.parametrize("arch", ["dit-small", "flux1-dev"])
def test_reduced_denoiser_forward(arch):
    cfg = config_lib.reduced(config_lib.get_config(arch))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))
    lat = jax.random.normal(jax.random.key(1), (2, 8, 8, cfg.in_channels))
    t = jnp.array([0.3, 0.7])
    text = None
    if cfg.text_dim > 0:
        text = jax.random.normal(jax.random.key(2),
                                 (2, cfg.n_text_tokens, cfg.text_dim))
    out = dit.dit_forward(params, lat, t, cfg, text)
    assert out.velocity.shape == lat.shape
    assert bool(jnp.isfinite(out.velocity).all())
    # FreqCa skip path consistency: from_crf(full crf) == full velocity
    v2 = dit.dit_from_crf(params, out.crf, t, cfg, 8, 8)
    assert bool(jnp.allclose(v2, out.velocity, atol=1e-5))


@pytest.mark.parametrize("arch", config_lib.ASSIGNED)
def test_reduced_decode_step(arch):
    """One serve_step (decode) on the reduced variant."""
    from repro.models import blocks
    cfg = config_lib.reduced(config_lib.get_config(arch))
    specs = steps_lib.model_specs(cfg)
    params = common.init_params(specs, jax.random.key(0))
    tok = jax.random.randint(jax.random.key(3), (BATCH, 1), 0,
                             cfg.vocab_size)
    if cfg.is_encdec:
        cache = encdec.decode_cache_zeros(cfg, BATCH, 8, jnp.float32)
        memory = jax.random.normal(jax.random.key(4), (BATCH, 8, cfg.d_model))
        logits, cache = encdec.decode_step(params, tok, memory, cache, cfg)
    else:
        cache = blocks.stack_cache_zeros(cfg, BATCH, 8, jnp.float32)
        logits, cache = transformer.decode_step(params, tok, cache, cfg)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
