"""Multi-resolution serving tests: (batch, shape) bucket signatures,
submit-time shape validation at engine / scheduler / router
boundaries, the shape-generic decode path, per-shape metrics on the
wire, the unbounded spectral-basis cache, and the non-power-of-two
bucket rule.

The engine e2e cases use a two-entry shape ladder (8px + 16px latents,
16 + 64 CRF tokens) through one shape-generic ``from_crf_fn`` — the
deployment shape the tentpole exists for — and pin the zero
steady-state recompile guarantee with the jit cache probe."""
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.core import frequency
from repro.core.cache import CachePolicy
from repro.serving import metrics as metrics_lib
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.fleet import FleetRouter
from repro.serving.scheduler import (Scheduler, ShapeMismatchError,
                                     bucket_for, bucket_signature,
                                     resolve_shape_key,
                                     validate_request_shape)

N_STEPS = 6
SIZES = (8, 16)


@pytest.fixture(scope="module")
def multi_fns():
    from repro.models import common, dit
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        # shape-generic: image side recovered from the token count, so
        # ONE callable serves every rung of the ladder
        tb = jnp.full((crf.shape[0],), t)
        side = int(round(crf.shape[1] ** 0.5)) * cfg.patch_size
        return dit.dit_from_crf(params, crf, tb, cfg, side, side)

    return cfg, full_fn, from_crf_fn


def shape_pair(cfg, size):
    return ((size, size, cfg.in_channels),
            ((size // cfg.patch_size) ** 2, cfg.d_model))


def make_multi_engine(multi_fns, max_batch=2, **kw):
    cfg, full_fn, from_crf_fn = multi_fns
    pairs = [shape_pair(cfg, s) for s in SIZES]
    return DiffusionEngine(full_fn, from_crf_fn, pairs[0][0], pairs[0][1],
                           CachePolicy(kind="freqca", interval=3),
                           n_steps=N_STEPS, max_batch=max_batch,
                           shapes=pairs[1:], **kw)


# ---------------------------------------------------------------------------
# engine: mixed-shape serving, zero steady recompiles, per-shape metrics
# ---------------------------------------------------------------------------

def test_multires_engine_serves_ladder_without_steady_recompiles(multi_fns):
    cfg = multi_fns[0]
    eng = make_multi_engine(multi_fns)
    assert eng.shapes == [shape_pair(cfg, s) for s in SIZES]
    eng.warmup()
    # warmed exactly the declared grid: shapes x buckets (one group)
    budget = eng.signature_budget()
    assert budget == len(SIZES) * 2          # buckets(2) = [1, 2]
    assert eng.compiled_buckets() == budget

    pre = eng.metrics_dict()["compile_misses"]
    for i, size in enumerate([8, 16, 8, 16, 8]):
        lat, crf = shape_pair(cfg, size)
        eng.submit(DiffusionRequest(request_id=i, seed=i,
                                    latent_shape=lat, crf_shape=crf))
    outs = eng.serve_until_drained()
    assert len(outs) == 5
    # the result tensors really are per-request resolution
    by_id = {o.request_id: o for o in outs}
    assert by_id[0].latents.shape == (8, 8, cfg.in_channels)
    assert by_id[1].latents.shape == (16, 16, cfg.in_channels)
    # zero steady-state recompiles across the whole mixed stream
    assert eng.metrics_dict()["compile_misses"] == pre
    assert eng.compiled_buckets() == budget

    s = eng.metrics.summary()
    assert s["shape_keys"] == len(SIZES)
    per = s["per_shape"]
    assert sum(v["requests"] for v in per.values()) == 5
    assert all(v["state_bytes_per_lane"] > 0 for v in per.values())


def test_multires_per_shape_state_bytes(multi_fns):
    cfg = multi_fns[0]
    eng = make_multi_engine(multi_fns)
    small = eng.state_bytes(1, *shape_pair(cfg, 8))
    large = eng.state_bytes(1, *shape_pair(cfg, 16))
    # 4x the pixels and tokens -> strictly more cache state
    assert large > small > 0


def test_undeclared_shape_rejected_at_submit(multi_fns):
    cfg = multi_fns[0]
    eng = make_multi_engine(multi_fns)
    bad_lat = (12, 12, cfg.in_channels)
    with pytest.raises(ShapeMismatchError):
        eng.submit(DiffusionRequest(request_id=0, seed=0,
                                    latent_shape=bad_lat))
    # the queue is untouched: nothing to drain, nothing half-submitted
    assert eng.scheduler.depth == 0
    # and a declared-but-inconsistent init_latents also fails fast
    lat, crf = shape_pair(cfg, 16)
    ref = np.zeros(shape_pair(cfg, 8)[0], np.float32)
    with pytest.raises(ShapeMismatchError):
        eng.submit(DiffusionRequest(request_id=1, seed=1, latent_shape=lat,
                                    crf_shape=crf, init_latents=ref,
                                    edit_strength=0.5))
    assert eng.scheduler.depth == 0


def test_declare_shape_after_construction(multi_fns):
    cfg = multi_fns[0]
    eng = make_multi_engine(multi_fns)
    lat, crf = shape_pair(cfg, 4)
    with pytest.raises(ShapeMismatchError):
        eng.submit(DiffusionRequest(request_id=0, seed=0, latent_shape=lat,
                                    crf_shape=crf))
    eng.declare_shape(lat, crf)
    # the scheduler shares the ladder by reference: now accepted
    eng.submit(DiffusionRequest(request_id=0, seed=0, latent_shape=lat,
                                crf_shape=crf))
    outs = eng.serve_until_drained()
    assert outs[0].latents.shape == lat


def test_partial_declaration_resolves_from_ladder(multi_fns):
    """A request naming only its latent shape completes to the unique
    ladder entry and serves at that resolution."""
    cfg = multi_fns[0]
    eng = make_multi_engine(multi_fns)
    eng.submit(DiffusionRequest(request_id=0, seed=0,
                                latent_shape=shape_pair(cfg, 16)[0]))
    outs = eng.serve_until_drained()
    assert outs[0].latents.shape == (16, 16, cfg.in_channels)


# ---------------------------------------------------------------------------
# shape-key resolution (pure helpers)
# ---------------------------------------------------------------------------

def test_resolve_shape_key_rules():
    a = ((8, 8, 4), (16, 64))
    b = ((16, 16, 4), (64, 64))
    ladder = {a, b}
    assert resolve_shape_key(None, None, a, ladder) == a
    assert resolve_shape_key(b[0], None, a, ladder) == b
    assert resolve_shape_key(None, b[1], a, ladder) == b
    # ambiguous half (shared crf shape) falls back to the default's half
    c = ((32, 32, 4), (64, 64))
    assert resolve_shape_key(None, b[1], a, {a, b, c}) == (a[0], b[1])
    # bare scheduler: nothing declared, nothing known
    assert resolve_shape_key(None, None, None, None) is None


def test_validate_request_shape_raises_outside_ladder():
    a = ((8, 8, 4), (16, 64))
    req = DiffusionRequest(request_id=0, seed=0, latent_shape=(9, 9, 4),
                           crf_shape=(16, 64))
    with pytest.raises(ShapeMismatchError):
        validate_request_shape(req, a, {a})
    assert validate_request_shape(
        DiffusionRequest(request_id=1, seed=1), a, {a}) == a


# ---------------------------------------------------------------------------
# bucket rule: non-power-of-two max_batch, signatures with a shape half
# ---------------------------------------------------------------------------

def test_bucket_rule_non_power_of_two():
    # the ladder is pow2 below max_batch, plus max_batch itself; a
    # request count between the last pow2 and max_batch lands on
    # max_batch (the smallest ladder rung >= n), never on a phantom
    # pow2 above it
    assert bucket_for(5, 6) == 6
    assert bucket_for(4, 6) == 4
    assert bucket_for(6, 6) == 6
    assert bucket_for(3, 6) == 4
    assert bucket_for(5, 7) == 7
    assert bucket_for(9, 12) == 12
    assert bucket_for(8, 12) == 8


def test_bucket_signature_carries_shape():
    shape = ((8, 8, 4), (16, 64))
    assert bucket_signature(3, 8) == (4, None)
    assert bucket_signature(3, 8, shape) == (4, shape)
    assert bucket_signature(5, 6, shape) == (6, shape)


# ---------------------------------------------------------------------------
# spectral basis cache: unbounded across a shape ladder
# ---------------------------------------------------------------------------

def test_low_band_basis_cache_is_unbounded():
    """Regression: a bounded LRU thrashed under a 20+-entry shape
    ladder — the basis for the first shape was evicted and rebuilt on
    every revisit.  Re-access of EVERY previously-built shape must be
    a cache hit."""
    frequency._low_band_basis_np.cache_clear()
    shapes = [16 + 4 * i for i in range(20)]
    for n in shapes:
        frequency._low_band_basis_np(n, 0.25, "dct")
    info = frequency._low_band_basis_np.cache_info()
    assert info.maxsize is None
    assert info.currsize >= len(shapes)
    misses = info.misses
    for n in shapes:                       # revisit in original order
        frequency._low_band_basis_np(n, 0.25, "dct")
    info = frequency._low_band_basis_np.cache_info()
    assert info.misses == misses           # zero rebuilds
    assert info.hits >= len(shapes)
    assert frequency._dct_basis_np.cache_info().maxsize is None


# ---------------------------------------------------------------------------
# per-shape metrics on the wire
# ---------------------------------------------------------------------------

def test_shape_metrics_roundtrip_and_merge():
    m = metrics_lib.ServeMetrics()
    m.observe_batch(2, 2, 0.1, 2, 6, shape_key="lat8x8x4/crf16x64")
    m.observe_batch(4, 3, 0.1, 2, 6, shape_key="lat16x16x4/crf64x64")
    m.observe_state_bytes(1000, shape_key="lat8x8x4/crf16x64")
    m.observe_state_bytes(4000, shape_key="lat16x16x4/crf64x64")
    r = metrics_lib.ServeMetrics.from_dict(m.to_dict())
    assert r.shape_batches == m.shape_batches
    assert r.state_bytes_by_shape == m.state_bytes_by_shape

    m2 = metrics_lib.ServeMetrics()
    m2.observe_batch(2, 1, 0.1, 2, 6, shape_key="lat8x8x4/crf16x64")
    m2.observe_state_bytes(1200, shape_key="lat8x8x4/crf16x64")
    merged = metrics_lib.ServeMetrics.merge([m.to_dict(), m2.to_dict()])
    sb = merged.shape_batches["lat8x8x4/crf16x64"]
    assert sb[0] == 2 and sb[1] == 3       # batches, requests summed
    # state bytes: max per shape across replicas, not a sum
    assert merged.state_bytes_by_shape["lat8x8x4/crf16x64"] == 1200
    assert merged.state_bytes_by_shape["lat16x16x4/crf64x64"] == 4000
    s = merged.summary()
    assert s["shape_keys"] == 2
    assert s["per_shape"]["lat8x8x4/crf16x64"]["requests"] == 3


def test_shape_metrics_tolerates_old_wire_format():
    """Snapshots from a pre-multires replica lack the per-shape dicts
    entirely; from_dict and merge must fill empties, not crash."""
    old = metrics_lib.ServeMetrics().to_dict()
    old.pop("shape_batches", None)
    old.pop("state_bytes_by_shape", None)
    r = metrics_lib.ServeMetrics.from_dict(old)
    assert r.shape_batches == {} and r.state_bytes_by_shape == {}
    merged = metrics_lib.ServeMetrics.merge(
        [old, {"shape_batches": {"k": [1, 1, 1.0]}}])
    assert merged.shape_batches == {"k": [1, 1, 1.0]}


# ---------------------------------------------------------------------------
# router boundary: fail fast before the counters move (unit, no procs)
# ---------------------------------------------------------------------------

class _FakeReplica:
    def __init__(self, idx=0):
        self.idx = idx
        self.inflight = {}
        self.healthy = True
        self.stopped = False
        self.probation = False
        self.sent = []

    def send(self, msg):
        self.sent.append(msg)


def _fake_router():
    router = FleetRouter(lambda: None, n_replicas=1)
    router.replicas = [_FakeReplica(0)]
    router.spill_slack = 4
    router._started = True
    return router


def test_router_rejects_bad_shape_before_counting():
    router = _fake_router()
    router._default_shape = ((8, 8, 4), (16, 64))
    router._shape_ladder = {((8, 8, 4), (16, 64)),
                            ((16, 16, 4), (64, 64))}
    before = dict(router.counters)
    with pytest.raises(ShapeMismatchError):
        router.submit(DiffusionRequest(request_id=0, seed=0,
                                       latent_shape=(9, 9, 4),
                                       crf_shape=(16, 64)))
    # synchronous rejection: no counter moved, nothing reached a
    # replica, so submitted == resolved + failed still holds trivially
    assert dict(router.counters) == before
    assert not router.replicas[0].sent
    assert not router.replicas[0].inflight


def test_router_validation_skipped_for_legacy_workers():
    """Workers predating shape metadata report no ladder: the router
    must not invent one (validation is a no-op, replicas still reject
    engine-side)."""
    router = _fake_router()
    assert router._shape_ladder is None and router._default_shape is None
    router._validate_shape(
        DiffusionRequest(request_id=0, seed=0, latent_shape=(9, 9, 4)))


# ---------------------------------------------------------------------------
# scheduler-level validation without an engine
# ---------------------------------------------------------------------------

def test_bare_scheduler_accepts_anything():
    # no declared default, no ladder: the pre-multires behavior
    sched = Scheduler(max_batch=4, max_wait_s=0.0, clock=lambda: 0.0)
    sched.submit(DiffusionRequest(request_id=0, seed=0,
                                  latent_shape=(9, 9, 4),
                                  crf_shape=(17, 3)), now=0.0)
    assert sched.depth == 1


def test_scheduler_with_ladder_rejects():
    a = ((8, 8, 4), (16, 64))
    sched = Scheduler(max_batch=4, max_wait_s=0.0, clock=lambda: 0.0,
                      default_shape=a, allowed_shapes={a})
    with pytest.raises(ShapeMismatchError):
        sched.submit(DiffusionRequest(request_id=0, seed=0,
                                      latent_shape=(9, 9, 4)), now=0.0)
    assert sched.depth == 0


# ---------------------------------------------------------------------------
# async engine: validation surfaces at submit, not inside a future
# ---------------------------------------------------------------------------

def test_async_submit_bad_shape_raises_no_orphan_future(multi_fns):
    from repro.serving.async_engine import AsyncDiffusionEngine
    cfg, full_fn, from_crf_fn = multi_fns
    pairs = [shape_pair(cfg, s) for s in SIZES]
    eng = AsyncDiffusionEngine(make_multi_engine(multi_fns))
    eng.start()
    try:
        with pytest.raises(ShapeMismatchError):
            eng.submit(DiffusionRequest(request_id=0, seed=0,
                                        latent_shape=(9, 9, 4)))
        assert not eng._futures            # no orphan future leaked
        fut = eng.submit(DiffusionRequest(
            request_id=1, seed=1, latent_shape=pairs[1][0],
            crf_shape=pairs[1][1]))
        assert isinstance(fut, Future)
        out = fut.result(timeout=60)
        assert out.latents.shape == pairs[1][0]
    finally:
        eng.shutdown()
