"""Fleet serving tests: ServeMetrics wire format (to_dict/from_dict
roundtrip, associative merge), end-to-end two-replica serving through
``FleetRouter`` (every future resolves, results bitwise-equal to the
in-process engine, zero steady-state recompiles per replica, routing
counters account for every placement), and the failure path (SIGKILL a
replica mid-stream: the router marks it unhealthy, requeues its
in-flight work onto the survivor, and every submitted future still
resolves exactly once).

``tiny_engine`` must stay module-level: the spawn start method pickles
the factory by reference and re-imports this module in the child.
"""
import time

import numpy as np
import pytest

from repro.serving.engine import DiffusionRequest
from repro.serving.fleet import FleetMetrics, FleetRouter
from repro.serving.metrics import ServeMetrics

SIZE = 8
N_STEPS = 6
MAX_BATCH = 4


def tiny_engine():
    """Zero-arg picklable factory: reduced DiT engine, built fresh in
    whichever process calls it (each fleet worker initialises its own
    params — deterministic from key(0), so replicas are identical)."""
    import jax
    import jax.numpy as jnp

    import repro.configs as config_lib
    from repro.core.cache import CachePolicy
    from repro.models import common, dit
    from repro.serving.engine import DiffusionEngine

    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, SIZE, SIZE)

    return DiffusionEngine(full_fn, from_crf_fn,
                           (SIZE, SIZE, cfg.in_channels),
                           (16, cfg.d_model),
                           CachePolicy(kind="freqca", interval=3),
                           n_steps=N_STEPS, max_batch=MAX_BATCH,
                           max_wait_s=0.05)


# ---------------------------------------------------------------------------
# ServeMetrics wire format (satellite: to_dict / from_dict / merge)
# ---------------------------------------------------------------------------

def _sample_metrics(n_batches=3, seed=0):
    m = ServeMetrics()
    for i in range(n_batches):
        m.observe_compile(hit=i > 0)
        m.observe_batch(4, 3, 0.1 * (i + 1 + seed), 2, N_STEPS,
                        lane_full=[2, 3, 2], group_key=f"g{seed}",
                        lane_errors=[0.01 * (i + 1)], lane_events=[1])
        m.observe_request(0.01 * i, 0.2 + 0.1 * i, n_full=2,
                          realized_error=0.02, budget_events=1)
        m.observe_queue_depth(i)
    m.observe_first_result(0.5 + seed)
    m.observe_state_bytes(1024)
    m.observe_compiled_signatures(3)
    m.observe_shed_events(seed)
    return m


def test_metrics_dict_roundtrip():
    m = _sample_metrics()
    d = m.to_dict()
    # plain python values only (pickles across a process boundary)
    assert all(isinstance(v, (int, float, list, dict, type(None)))
               for v in d.values()), d
    m2 = ServeMetrics.from_dict(d)
    assert m2.to_dict() == d
    assert m2.summary() == m.summary()


def test_metrics_merge_is_lossless_and_associative():
    parts = [_sample_metrics(seed=s) for s in range(3)]
    merged = ServeMetrics.merge(parts)
    # counters sum, observations concatenate (exact fleet percentiles)
    assert merged.n_requests == sum(p.n_requests for p in parts)
    assert merged.compile_misses == sum(p.compile_misses for p in parts)
    assert sorted(merged.request_latencies) == sorted(
        x for p in parts for x in p.request_latencies)
    # ttfr is the fleet minimum; signatures the fleet total
    assert merged.time_to_first_result_s == min(
        p.time_to_first_result_s for p in parts)
    assert merged.compiled_signatures == 9
    # associativity: pairwise folds == one flat fold (dicts and
    # instances are interchangeable parts)
    left = ServeMetrics.merge(
        [ServeMetrics.merge(parts[:2]).to_dict(), parts[2]])
    assert left.summary() == merged.summary()
    right = ServeMetrics.merge(
        [parts[0], ServeMetrics.merge([p.to_dict() for p in parts[1:]])])
    assert right.summary() == merged.summary()


def test_fleet_metrics_summary_sections():
    snaps = {i: _sample_metrics(seed=i).to_dict() for i in range(2)}
    fm = FleetMetrics(snaps, routing={"affinity_hits": 5, "spills": 1},
                      meta={0: {"warmup_compiles": 1},
                            1: {"warmup_compiles": 0}})
    s = fm.summary()
    assert s["fleet"]["replicas"] == 2
    assert s["fleet"]["requests"] == 6
    # steady recompiles = misses beyond each replica's boot warmup
    assert s["per_replica"][0]["steady_recompiles"] == 0
    assert s["per_replica"][1]["steady_recompiles"] == 1
    assert s["routing"]["spills"] == 1
    assert fm.steady_recompiles(7) is None   # unknown replica


# ---------------------------------------------------------------------------
# launcher flag (satellite: --replicas 1 stays on the in-process path)
# ---------------------------------------------------------------------------

def test_replicas_flag_defaults_to_inprocess():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([])
    assert args.replicas == 1          # default: in-process engine path
    args = build_parser().parse_args(["--replicas", "2"])
    assert args.replicas == 2


# ---------------------------------------------------------------------------
# end-to-end fleet serving (slow: boots worker processes)
# ---------------------------------------------------------------------------

def _requests(n):
    return [DiffusionRequest(request_id=i, seed=i) for i in range(n)]


def test_fleet_two_replicas_end_to_end():
    n = 10
    router = FleetRouter(tiny_engine, n_replicas=2)
    try:
        router.start()
        assert all(r.healthy for r in router.replicas)
        assert router.spill_slack == MAX_BATCH   # from ready metadata
        futs = [router.submit(r) for r in _requests(n)]
        assert router.drain(timeout=300.0)
        outs = [f.result(timeout=10.0) for f in futs]
        fm = router.fleet_metrics()
    finally:
        router.shutdown(drain=False)

    assert sorted(o.request_id for o in outs) == list(range(n))
    # bitwise-identical to the in-process engine on the same stream:
    # per-request sampling is deterministic in the seed, independent of
    # which replica / batch composition served it
    eng = tiny_engine()
    eng.warmup()
    for r in _requests(n):
        eng.submit(r)
    ref = {o.request_id: np.asarray(o.latents)
           for o in eng.serve_until_drained()}
    for o in outs:
        assert np.array_equal(np.asarray(o.latents), ref[o.request_id]), \
            f"request {o.request_id} diverged from in-process engine"

    s = fm.summary()
    assert s["fleet"]["requests"] == n
    assert s["fleet"]["replicas"] == 2
    # the fleet invariant: once warm, no replica ever compiles again
    for idx, pr in s["per_replica"].items():
        assert pr["steady_recompiles"] == 0, (idx, pr)
    rt = s["routing"]
    assert rt["submitted"] == rt["resolved"] == n
    assert rt["failed"] == 0 and rt["duplicate_results"] == 0
    assert rt["requeued"] == 0 and rt["replicas_lost"] == 0
    # every placement is accounted for: one new group for the default
    # policy, the rest affinity follows or load spills
    assert rt["new_groups"] >= 1
    assert rt["new_groups"] + rt["affinity_hits"] + rt["spills"] == n


def test_replica_crash_requeues_onto_survivor():
    n = 8
    router = FleetRouter(tiny_engine, n_replicas=2,
                         health_interval_s=0.1)
    try:
        router.start()
        futs = [router.submit(r) for r in _requests(n)]
        # SIGKILL the replica holding the most in-flight work while the
        # stream is mid-flight — the crash case (SIGTERM would drain)
        with router._lock:
            victim = max(router.replicas, key=lambda r: len(r.inflight))
            assert victim.inflight, "victim had no in-flight work"
        victim.proc.kill()
        outs = [f.result(timeout=300.0) for f in futs]  # exactly once
        # death observed and accounted
        deadline = time.monotonic() + 10.0
        while victim.healthy and time.monotonic() < deadline:
            time.sleep(0.05)
        st = router.status()
    finally:
        router.shutdown(drain=False)

    assert sorted(o.request_id for o in outs) == list(range(n))
    assert not victim.healthy
    assert st["healthy_replicas"] == 1
    rt = st["counters"]
    assert rt["replicas_lost"] == 1
    assert rt["requeued"] >= 1, rt          # orphans moved to the survivor
    assert rt["resolved"] == n and rt["failed"] == 0
    assert rt["duplicate_results"] == 0
    survivor = next(r for r in router.replicas if r is not victim)
    assert not survivor.inflight


def test_router_rejects_bad_config():
    with pytest.raises(ValueError):
        FleetRouter(tiny_engine, n_replicas=0)
    router = FleetRouter(tiny_engine, n_replicas=1)
    with pytest.raises(RuntimeError):       # not started yet
        router.submit(DiffusionRequest(request_id=0, seed=0))


# ---------------------------------------------------------------------------
# exactly-once futures: a seeded double-resolution is absorbed, counted
# ---------------------------------------------------------------------------

class _FakeReplica:
    """Just enough of ``Replica`` for the router's result path: an
    inflight table.  No process is spawned."""

    def __init__(self):
        self.inflight = {}
        self.healthy = True
        self.stopped = False


def test_double_set_result_absorbed_by_duplicate_counter():
    """The requeue race, replayed deterministically: a replica dies
    after shipping a result, its in-flight request is requeued onto a
    survivor under a NEW token with the SAME future, then both results
    arrive.  The second resolution must bump ``duplicate_results`` —
    never raise ``InvalidStateError`` into the receiver thread."""
    from concurrent.futures import Future

    router = FleetRouter(tiny_engine, n_replicas=2)   # never started
    dead, survivor = _FakeReplica(), _FakeReplica()
    req = DiffusionRequest(request_id=7, seed=0)
    fut = Future()
    dead.inflight[0] = (req, fut)       # original placement
    survivor.inflight[1] = (req, fut)   # requeued under a new token

    router._finish(dead, 0, value="res-a")      # first result wins
    router._finish(survivor, 1, value="res-b")  # late duplicate

    assert fut.result(timeout=1) == "res-a"
    assert router.counters["duplicate_results"] == 1
    assert router.counters["resolved"] == 2     # both tokens retired
    assert not dead.inflight and not survivor.inflight


def test_finish_is_idempotent_per_token():
    """A token already popped (requeued/cancelled meanwhile) is a
    no-op: no counter bump, no resolution attempt."""
    from concurrent.futures import Future

    router = FleetRouter(tiny_engine, n_replicas=1)
    r = _FakeReplica()
    fut = Future()
    r.inflight[5] = (DiffusionRequest(request_id=1, seed=0), fut)
    router._finish(r, 5, value="first")
    router._finish(r, 5, value="again")         # token already gone
    assert fut.result(timeout=1) == "first"
    assert router.counters["duplicate_results"] == 0
    assert router.counters["resolved"] == 1


def test_async_engine_absorbs_duplicate_resolution():
    """The async worker's ``_serve`` uses the same exactly-once guard:
    a future that somehow resolved early must degrade to the
    ``duplicate_results`` metric, not kill the worker thread."""
    from concurrent.futures import Future

    from repro.serving.async_engine import AsyncDiffusionEngine
    from repro.serving.metrics import ServeMetrics

    class _Eng:
        def __init__(self):
            self.metrics = ServeMetrics()

        def execute_plan(self, plan):
            return ["res"]

    aeng = AsyncDiffusionEngine.__new__(AsyncDiffusionEngine)
    aeng.engine = _Eng()
    aeng.metrics = aeng.engine.metrics
    aeng._t0 = None

    fut = Future()
    # repro: allow[future-guard]: seeding the double resolution this test exists to exercise
    fut.set_result("early")
    aeng._serve(plan=None, futs=[fut])  # must not raise
    assert fut.result() == "early"
    assert aeng.metrics.duplicate_results == 1
    assert aeng.metrics.to_dict()["duplicate_results"] == 1
