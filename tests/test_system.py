"""End-to-end behaviour tests: training drives loss down, the serving
engine serves batches with the expected compute saving, checkpoints
round-trip, and the backbone-denoiser wrapping (FreqCa on assigned
architectures) works."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.checkpointing import checkpoint
from repro.core.cache import CachePolicy
from repro.data import synthetic
from repro.diffusion import sampler, schedule, training
from repro.launch.train import train_dit, train_lm
from repro.models import common, dit
from repro.serving.engine import DiffusionEngine, DiffusionRequest


def test_dit_training_reduces_loss(tmp_path):
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))
    from repro.optim import adamw
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    opt = adamw.init(opt_cfg, params)

    def apply_fn(p, x_t, t):
        return dit.dit_forward(p, x_t, t, cfg).velocity

    @jax.jit
    def step(params, opt, latents, rng):
        (l, m), g = jax.value_and_grad(
            lambda p: training.rf_loss(apply_fn, p, {"latents": latents},
                                       rng), has_aux=True)(params)
        params, opt, _ = adamw.update(opt_cfg, g, opt, params)
        return params, opt, l

    losses = []
    for i in range(60):
        latents = synthetic.shapes_batch(jax.random.key(i), 8, size=8,
                                         channels=cfg.in_channels)
        params, opt, l = step(params, opt, latents, jax.random.key(1000 + i))
        losses.append(float(l))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.8, losses[:3]


def test_lm_training_reduces_loss():
    cfg = config_lib.reduced(config_lib.get_config("yi-9b"))
    _, losses = train_lm(cfg, steps=15, batch=4, seq=32, ckpt_dir="")
    assert losses[-1] < losses[0]


def test_serving_engine_end_to_end():
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, 8, 8)

    eng = DiffusionEngine(full_fn, from_crf_fn, (8, 8, cfg.in_channels),
                          (16, cfg.d_model),
                          CachePolicy(kind="freqca", interval=5),
                          n_steps=20, max_batch=4)
    for i in range(6):
        eng.submit(DiffusionRequest(request_id=i, seed=i))
    out1 = eng.run_batch()
    out2 = eng.run_batch()
    assert len(out1) == 4 and len(out2) == 2
    assert all(jnp.isfinite(o.latents).all() for o in out1 + out2)
    assert out1[0].n_full_steps < 20  # compute actually skipped


def test_editing_request_denoises_from_reference():
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, 8, 8)

    eng = DiffusionEngine(full_fn, from_crf_fn, (8, 8, cfg.in_channels),
                          (16, cfg.d_model),
                          CachePolicy(kind="freqca", interval=3),
                          n_steps=10, max_batch=2)
    ref_img = synthetic.shapes_batch(jax.random.key(5), 1, size=8,
                                     channels=cfg.in_channels)[0]
    eng.submit(DiffusionRequest(request_id=0, seed=0, init_latents=ref_img,
                                edit_strength=0.4))
    out = eng.run_batch()
    assert jnp.isfinite(out[0].latents).all()


def test_backbone_denoiser_freqca():
    """FreqCa on an assigned architecture (mamba2) used as denoiser."""
    cfg = config_lib.reduced(config_lib.get_config("mamba2-370m"))
    params = common.init_params(dit.backbone_denoiser_specs(cfg),
                                jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.backbone_denoiser_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        return dit.backbone_denoiser_from_crf(params, crf, cfg, 8, 8)

    x0 = jax.random.normal(jax.random.key(1), (2, 8, 8, 4))
    ts = schedule.timesteps(12)
    res = sampler.sample(full_fn, from_crf_fn, x0, ts,
                         CachePolicy(kind="freqca", interval=4, rho=0.25),
                         crf_shape=(2, 16, cfg.d_model))
    assert bool(jnp.isfinite(res.x).all())
    assert int(res.n_full) < 12


def test_checkpoint_roundtrip(tmp_path):
    cfg = config_lib.reduced(config_lib.get_config("yi-9b"))
    from repro.models import transformer
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, params, name="t")
    assert checkpoint.latest_step(d, "t") == 7
    restored = checkpoint.restore(d, 7, params, name="t")
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_lm_engine_generates():
    from repro.serving.engine import LMEngine
    cfg = config_lib.reduced(config_lib.get_config("yi-9b"))
    from repro.models import transformer
    params = common.init_params(transformer.lm_specs(cfg), jax.random.key(0))
    eng = LMEngine(params, cfg, max_len=32)
    prompt = jax.random.randint(jax.random.key(0), (2, 4), 0, cfg.vocab_size)
    out = eng.generate(prompt, n_new=6)
    assert out.shape == (2, 10)
