"""Property tests for the lock-order cycle detector
(``repro.analysis.graphs``), which both the static ``lock-order`` pass
and the runtime sanitizer stand on.

Hypothesis (via the ``tests/hypothesis_compat.py`` ci profile — the
shim skips gracefully in the bare tier-1 env) drives two properties:

* **soundness**: a random DAG — edges drawn only forward along a
  random topological order — is NEVER flagged;
* **completeness**: any random graph with an injected directed cycle
  is ALWAYS flagged, and the reported witness is a genuine cycle of
  the input graph.

Deterministic twins at the bottom keep the core cases covered when
hypothesis isn't installed.
"""
import random

from hypothesis_compat import HAS_HYPOTHESIS, given, st  # noqa: F401

from repro.analysis.graphs import find_cycle, has_path, would_close_cycle

if HAS_HYPOTHESIS:
    import hypothesis


def _dag_from(seed: int, n: int, density: float):
    """Random DAG: nodes 0..n-1 in a shuffled topological order, edges
    only from earlier to later in that order."""
    rng = random.Random(seed)
    order = list(range(n))
    rng.shuffle(order)
    rank = {v: i for i, v in enumerate(order)}
    graph = {v: set() for v in range(n)}
    for a in range(n):
        for b in range(n):
            if a != b and rank[a] < rank[b] and rng.random() < density:
                graph[a].add(b)
    return graph


def _check_witness(graph, cycle):
    assert cycle[0] == cycle[-1], cycle
    assert len(cycle) >= 2
    for a, b in zip(cycle, cycle[1:], strict=False):
        assert b in graph.get(a, ()), (cycle, graph)


if HAS_HYPOTHESIS:

    @given(st.integers(0, 2**32 - 1), st.integers(1, 12),
           st.floats(0.0, 1.0))
    def test_random_dag_never_flags(seed, n, density):
        graph = _dag_from(seed, n, density)
        assert find_cycle(graph) is None

    @given(st.integers(0, 2**32 - 1), st.integers(2, 12),
           st.floats(0.0, 1.0),
           st.integers(2, 12))
    def test_injected_cycle_always_flags(seed, n, density, cyc_len):
        rng = random.Random(seed ^ 0x5EED)
        graph = _dag_from(seed, n, density)
        # inject a directed cycle over a random node subset
        k = min(cyc_len, n)
        members = rng.sample(range(n), k)
        for a, b in zip(members, members[1:] + members[:1],
                        strict=True):
            graph.setdefault(a, set()).add(b)
        cycle = find_cycle(graph)
        assert cycle is not None, (members, graph)
        _check_witness(graph, cycle)

    @given(st.integers(0, 2**32 - 1), st.integers(1, 10),
           st.floats(0.0, 0.6))
    def test_would_close_cycle_matches_reachability(seed, n, density):
        graph = _dag_from(seed, n, density)
        rng = random.Random(seed ^ 0xC1C1E)
        src = rng.randrange(n)
        dst = rng.randrange(n)
        # adding src->dst closes a cycle iff src is reachable from dst
        assert would_close_cycle(graph, src, dst) == \
            has_path(graph, dst, src)
        if would_close_cycle(graph, src, dst):
            graph.setdefault(src, set()).add(dst)
            assert find_cycle(graph) is not None

    @hypothesis.settings(max_examples=10)
    @given(st.integers(0, 2**32 - 1))
    def test_detector_is_iterative_on_deep_graphs(seed):
        # a 5000-node path would blow the recursion limit on a
        # recursive DFS; the detector must be iterative
        n = 5000
        graph = {i: {i + 1} for i in range(n - 1)}
        assert find_cycle(graph) is None
        graph[n - 1] = {seed % n}     # any back edge closes a cycle
        _check_witness(graph, find_cycle(graph))


# --- deterministic twins (run in the bare no-hypothesis env) -----------

def test_dag_never_flags_deterministic():
    for seed in range(25):
        for density in (0.1, 0.5, 0.9):
            assert find_cycle(_dag_from(seed, 9, density)) is None


def test_injected_cycle_always_flags_deterministic():
    for seed in range(25):
        rng = random.Random(seed)
        graph = _dag_from(seed, 9, 0.3)
        members = rng.sample(range(9), rng.randint(2, 9))
        for a, b in zip(members, members[1:] + members[:1],
                        strict=True):
            graph.setdefault(a, set()).add(b)
        cycle = find_cycle(graph)
        assert cycle is not None
        _check_witness(graph, cycle)


def test_two_node_inversion():
    assert find_cycle({"A": {"B"}, "B": {"A"}}) is not None
    assert find_cycle({"A": {"B"}}) is None


def test_self_loop_is_a_cycle():
    # the passes never emit self-edges (reentrancy), but the detector
    # itself must be honest about them
    cycle = find_cycle({"A": {"A"}})
    _check_witness({"A": {"A"}}, cycle)


def test_empty_and_single():
    assert find_cycle({}) is None
    assert find_cycle({"A": set()}) is None
