"""Chaos suite: the self-healing fleet under deterministic faults.

Unit tier (no processes): supervisor backoff policy, ``FaultInjector``
spec resolution, wire-format compatibility for the new counters, and
the router's quarantine / isolation-probe / backpressure / shed logic
replayed on fake in-process replicas.

Integration tier (spawns real workers, slow): SIGKILL mid-stream with
supervisor restart and a post-rejoin wave, a hung worker killed
exactly once and restarted, a restart that succeeds after one injected
boot failure, a crash-looping slot retired permanently, poison
quarantine with healthy traffic untouched, and SIGKILL during an
active ``drain()``.

``tiny_engine`` and the fake-engine factory must stay module-level:
the spawn start method pickles factories by reference and re-imports
this module in the child.
"""
import pickle
import threading
import time
from concurrent.futures import Future

import pytest

from repro.serving.engine import DiffusionRequest
from repro.serving.fleet import (FaultInjector, FleetRouter,
                                 FleetSupervisor, PoisonRequestError,
                                 Replica)
from repro.serving.fleet.worker import worker_main
from repro.serving.metrics import ServeMetrics

SIZE = 8
N_STEPS = 6
MAX_BATCH = 4


def tiny_engine():
    """Zero-arg picklable factory: reduced DiT engine, built fresh in
    whichever process calls it (deterministic from key(0), so replicas
    and incarnations are identical)."""
    import jax
    import jax.numpy as jnp

    import repro.configs as config_lib
    from repro.core.cache import CachePolicy
    from repro.models import common, dit
    from repro.serving.engine import DiffusionEngine

    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, SIZE, SIZE)

    return DiffusionEngine(full_fn, from_crf_fn,
                           (SIZE, SIZE, cfg.in_channels),
                           (16, cfg.d_model),
                           CachePolicy(kind="freqca", interval=3),
                           n_steps=N_STEPS, max_batch=MAX_BATCH,
                           max_wait_s=0.05)


def _requests(n, start=0, max_error=None):
    return [DiffusionRequest(request_id=start + i, seed=start + i,
                             max_error=max_error) for i in range(n)]


# ---------------------------------------------------------------------------
# supervisor policy (unit)
# ---------------------------------------------------------------------------

class _StubRouter:
    n_replicas = 2


def test_backoff_exponential_and_capped():
    sup = FleetSupervisor(_StubRouter(), max_restarts=3,
                          backoff_base_s=0.5, backoff_cap_s=4.0)
    assert sup.backoff_s(0) == 0.5
    assert sup.backoff_s(1) == 1.0
    assert sup.backoff_s(2) == 2.0
    assert sup.backoff_s(3) == 4.0
    assert sup.backoff_s(10) == 4.0          # capped
    with pytest.raises(ValueError):
        FleetSupervisor(_StubRouter(), max_restarts=0)


def test_can_recover_tracks_retired_slots():
    sup = FleetSupervisor(_StubRouter(), max_restarts=1)
    assert sup.can_recover()
    sup.retired_slots.add(0)
    assert sup.can_recover()                 # slot 1 could still restart
    sup.retired_slots.add(1)
    assert not sup.can_recover()


# ---------------------------------------------------------------------------
# fault injector (unit)
# ---------------------------------------------------------------------------

def test_fault_specs_are_scoped_and_deterministic():
    fi = (FaultInjector(seed=7)
          .kill_after_submits(2, slot=0, start_n=0)
          .fail_boot(slot=0, start_n=1)
          .mute_pings_after(3)                     # every slot, every boot
          .delay_results(0.1, jitter_s=0.05, slot=1))
    assert fi.spec_for(0, 0) == {"kill_after_submits": 2,
                                 "ignore_pings_after": 3}
    assert fi.spec_for(0, 1) == {"boot_fail": True,
                                 "ignore_pings_after": 3}
    assert fi.spec_for(0, 2) == {"ignore_pings_after": 3}
    s1 = fi.spec_for(1, 0)
    assert 0.1 <= s1["result_delay_s"] <= 0.15
    # deterministic: same (seed, slot, start_n) -> same jitter; a
    # different incarnation draws a different one
    fi2 = FaultInjector(seed=7).delay_results(0.1, jitter_s=0.05, slot=1)
    assert fi2.spec_for(1, 0)["result_delay_s"] == s1["result_delay_s"]
    assert fi2.spec_for(1, 1)["result_delay_s"] != s1["result_delay_s"]


def test_fault_later_rules_win():
    fi = FaultInjector().kill_after_submits(5).kill_after_submits(1, slot=0)
    assert fi.spec_for(0, 0) == {"kill_after_submits": 1}
    assert fi.spec_for(1, 0) == {"kill_after_submits": 5}


# ---------------------------------------------------------------------------
# wire format: stale_pong_kills counter + old-schema tolerance (satellite)
# ---------------------------------------------------------------------------

def test_stale_pong_kills_on_the_wire():
    m = ServeMetrics()
    m.observe_stale_pong_kill()
    m.observe_stale_pong_kill()
    assert m.summary()["stale_pong_kills"] == 2
    assert ServeMetrics.from_dict(m.to_dict()).stale_pong_kills == 2
    merged = ServeMetrics.merge([m, m.to_dict()])
    assert merged.stale_pong_kills == 4


def test_wire_format_tolerates_older_schema():
    """A snapshot written before the new counters existed (a replica
    one release behind its router) must still load and merge."""
    old = ServeMetrics().to_dict()
    del old["stale_pong_kills"]
    assert ServeMetrics.from_dict(old).stale_pong_kills == 0
    # partial router-side snapshots carry only the counters the router
    # can observe — merge fills everything else with defaults
    merged = ServeMetrics.merge(
        [old, {"stale_pong_kills": 3, "duplicate_results": 1}])
    assert merged.stale_pong_kills == 3
    assert merged.duplicate_results == 1


def test_fleet_metrics_fold_router_snap():
    from repro.serving.fleet import FleetMetrics
    fm = FleetMetrics({0: ServeMetrics().to_dict()},
                      router_snap={"stale_pong_kills": 2,
                                   "duplicate_results": 1})
    merged = fm.merged()
    assert merged.stale_pong_kills == 2
    assert merged.duplicate_results == 1


def test_launcher_robustness_flags():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([])
    assert args.max_restarts == 2 and args.max_inflight == 0
    args = build_parser().parse_args(
        ["--max-restarts", "0", "--max-inflight", "8"])
    assert args.max_restarts == 0 and args.max_inflight == 8


# ---------------------------------------------------------------------------
# quarantine / probe / backpressure logic on fake replicas (unit)
# ---------------------------------------------------------------------------

class _FakeReplica:
    """Enough of ``Replica`` for the router's routing/failure paths:
    an inflight table plus a recording ``send``.  No process."""

    def __init__(self, idx=0):
        self.idx = idx
        self.inflight = {}
        self.healthy = True
        self.stopped = False
        self.probation = False
        self.sent = []

    def send(self, msg):
        self.sent.append(msg)


def _fake_router(replicas, **kw):
    router = FleetRouter(tiny_engine, n_replicas=max(len(replicas), 1),
                         **kw)
    router.replicas = replicas
    router.spill_slack = MAX_BATCH
    router._started = True
    return router


def test_solo_death_at_budget_is_quarantined():
    dead, survivor = _FakeReplica(0), _FakeReplica(1)
    router = _fake_router([dead, survivor], retry_budget=2)
    fut = Future()
    # already implicated in one death; it was ALONE on this replica
    dead.inflight[0] = (DiffusionRequest(request_id=9, seed=9), fut, 1)
    dead.healthy = False
    router._on_replica_down(dead)
    with pytest.raises(PoisonRequestError):
        fut.result(timeout=1)
    assert router.counters["poison_quarantined"] == 1
    assert not survivor.sent                 # never requeued


def test_cohort_death_probes_instead_of_quarantining():
    """A request at its budget that died in a COHORT is parked for a
    solo isolation probe — a healthy bystander must never be failed on
    circumstantial evidence."""
    dead = _FakeReplica(0)
    busy, idle = _FakeReplica(1), _FakeReplica(2)
    router = _fake_router([dead, busy, idle], retry_budget=2)
    sus_fut, fresh_fut = Future(), Future()
    dead.inflight[0] = (DiffusionRequest(request_id=1, seed=1), sus_fut, 1)
    dead.inflight[1] = (DiffusionRequest(request_id=2, seed=2), fresh_fut, 0)
    router._on_replica_down(dead)

    # under budget -> plain requeue; at budget in cohort -> probation
    assert router.counters["probations"] == 1
    assert router.counters["poison_quarantined"] == 0
    assert not sus_fut.done() and not fresh_fut.done()
    probed = busy if busy.probation else idle
    other = idle if probed is busy else busy
    assert probed.probation and len(probed.inflight) == 1
    assert len(other.inflight) == 1          # the bystander requeue
    # the probe comes back clean: bystander resolves, replica released
    token = next(iter(probed.inflight))
    router._finish(probed, token, value="ok")
    assert sus_fut.result(timeout=1) == "ok"
    assert not probed.probation


def test_probation_replica_excluded_from_routing():
    normal, probed = _FakeReplica(0), _FakeReplica(1)
    probed.probation = True
    router = _fake_router([normal, probed])
    for req in _requests(4):
        fut = router.submit(req)
        assert not fut.done()
    assert len(normal.inflight) == 4 and not probed.inflight


def test_backpressure_blocks_until_capacity_frees():
    rep = _FakeReplica(0)
    router = _fake_router([rep], max_inflight=1)
    router.submit(DiffusionRequest(request_id=0, seed=0))
    assert len(rep.inflight) == 1

    placed = threading.Event()

    def second():
        router.submit(DiffusionRequest(request_id=1, seed=1))
        placed.set()

    th = threading.Thread(target=second, daemon=True)
    th.start()
    assert not placed.wait(0.3)              # blocked at the cap
    assert router.counters["backpressure_waits"] == 1
    token = next(iter(rep.inflight))
    router._finish(rep, token, value="done")  # frees the slot
    assert placed.wait(5.0)
    th.join(5.0)
    assert len(rep.inflight) == 1
    assert router.counters["peak_inflight"] == 1


def test_backpressure_sheds_quality_once():
    rep = _FakeReplica(0)
    router = _fake_router([rep], max_inflight=1, shed_factor=4.0)
    router.submit(DiffusionRequest(request_id=0, seed=0, max_error=0.1))

    def second():
        router.submit(DiffusionRequest(request_id=1, seed=1, max_error=0.1))

    th = threading.Thread(target=second, daemon=True)
    th.start()
    deadline = time.monotonic() + 5.0
    while router.counters["router_shed_events"] == 0 \
            and time.monotonic() < deadline:
        time.sleep(0.01)
    assert router.counters["router_shed_events"] == 1
    router._finish(rep, next(iter(rep.inflight)), value="done")
    th.join(5.0)
    (req, _, _), = rep.inflight.values()
    assert req.max_error == pytest.approx(0.4)   # relaxed once, 0.1 * 4


# ---------------------------------------------------------------------------
# worker drain-thread dedupe (satellite) — worker_main run in a thread
# ---------------------------------------------------------------------------

class _FakeScheduler:
    depth = 0


class _FakeServeEngine:
    max_batch = MAX_BATCH
    buckets = [1, 2, 4]
    scheduler = _FakeScheduler()
    # shape metadata the worker reports in its ready handshake (a real
    # engine's ladder always includes its default shape)
    shapes = [((8, 8, 4), (16, 64))]
    latent_shape = (8, 8, 4)
    crf_shape = (16, 64)

    def warmup(self, buckets=None, lane_policy_sets=(), policies=(),
               shapes=()):
        return 0.0

    def metrics_dict(self):
        return {"compile_misses": 0}


def _fake_serve_engine():
    return _FakeServeEngine()


class _SlowDrainAsync:
    """AsyncDiffusionEngine stand-in whose drain takes long enough to
    overlap the router's 0.25 s drain re-sends."""
    drains = 0

    def __init__(self, engine):
        self.engine = engine

    def start(self):
        return self

    def pending(self):
        return 0

    def drain(self):
        type(self).drains += 1
        time.sleep(0.6)

    def shutdown(self, drain=True):
        pass


def test_worker_coalesces_overlapping_drains(monkeypatch):
    import repro.serving.async_engine as ae
    monkeypatch.setattr(ae, "AsyncDiffusionEngine", _SlowDrainAsync)
    _SlowDrainAsync.drains = 0
    import multiprocessing as mp
    parent, child = mp.Pipe()
    payload = pickle.dumps((_fake_serve_engine, {}))
    th = threading.Thread(target=worker_main,
                          args=(child, {}, payload, None), daemon=True)
    th.start()
    try:
        assert parent.poll(10.0)
        assert parent.recv()[0] == "ready"
        # the router re-sends ("drain",) every tick; the worker must
        # run ONE flusher thread, not one per command
        for _ in range(5):
            parent.send(("drain",))
            time.sleep(0.05)
        flushers = [t for t in threading.enumerate()
                    if t.name == "fleet-worker-drain" and t.is_alive()]
        assert len(flushers) == 1, flushers
        assert parent.poll(10.0)
        assert parent.recv() == ("drained",)
        assert _SlowDrainAsync.drains == 1   # 5 commands, one flush
    finally:
        parent.send(("stop",))
        th.join(10.0)
    assert not th.is_alive()


# ---------------------------------------------------------------------------
# boot-failure cleanup (satellite) — cheap: boot faults fire pre-import
# ---------------------------------------------------------------------------

def test_boot_error_is_killed_joined_and_closed():
    router = FleetRouter(tiny_engine, n_replicas=1,
                         fault_injector=FaultInjector().fail_boot())
    with pytest.raises(RuntimeError, match="failed to boot"):
        router.start()
    (r,) = router.replicas
    assert not r.proc.is_alive()             # killed AND joined, no zombie
    assert r.proc.exitcode is not None
    assert r.conn.closed                     # pipe fds released


def test_boot_timeout_is_killed_joined_and_closed():
    router = FleetRouter(tiny_engine, n_replicas=1, boot_timeout_s=1.0,
                         fault_injector=FaultInjector().hang_boot(60.0))
    with pytest.raises(TimeoutError):
        router.start()
    (r,) = router.replicas
    assert not r.proc.is_alive()
    assert r.conn.closed


def test_replica_kill_is_latched():
    r = Replica(0, tiny_engine, fault={"boot_hang_s": 60.0})
    try:
        assert r.kill() is True              # fires
        assert r.kill() is False             # latched: at most once
        assert r.kill_requested
    finally:
        r.destroy()
    assert not r.proc.is_alive()
    assert r.conn.closed


# ---------------------------------------------------------------------------
# integration: real workers under injected faults (slow)
# ---------------------------------------------------------------------------

def _wait(predicate, timeout_s, period=0.1):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(period)
    return predicate()


def test_killed_replica_restarts_and_serves_post_rejoin():
    """The tentpole end-to-end: SIGKILL-equivalent crash mid-stream,
    orphans requeued, slot restarted, and the restarted incarnation
    serves a second wave with zero steady-state recompiles."""
    n = 8
    faults = FaultInjector().kill_after_submits(2, slot=0, start_n=0)
    router = FleetRouter(tiny_engine, n_replicas=2, max_restarts=2,
                         restart_backoff_base_s=0.1, max_inflight=16,
                         health_interval_s=0.1, fault_injector=faults)
    try:
        router.start()
        futs = [router.submit(r) for r in _requests(n)]
        assert router.drain(timeout=300.0)
        assert _wait(lambda: router.status()["healthy_replicas"] == 2,
                     timeout_s=120.0)
        futs += [router.submit(r) for r in _requests(n, start=n)]
        assert router.drain(timeout=300.0)
        outs = [f.result(timeout=10.0) for f in futs]   # exactly once
        fm = router.fleet_metrics()
        st = router.status()
    finally:
        router.shutdown(drain=False)

    assert sorted(o.request_id for o in outs) == list(range(2 * n))
    rt = st["counters"]
    assert rt["replicas_lost"] >= 1 and rt["requeued"] >= 1
    assert rt["submitted"] == rt["resolved"] == 2 * n
    assert rt["failed"] == 0 and rt["poison_quarantined"] == 0
    assert rt["peak_inflight"] <= 2 * 16
    assert st["supervisor"]["restarts"] >= 1
    assert st["replicas"][0]["start_n"] == 1    # the second incarnation
    s = fm.summary()
    # the restarted worker re-warmed at boot: serving stayed compile-free
    for idx, pr in s["per_replica"].items():
        assert pr["steady_recompiles"] == 0, (idx, pr)
    assert s["per_replica"][0]["requests"] > 0  # rejoined AND served


def test_hung_worker_killed_once_and_restarted():
    """A worker that stops answering pings (but stays alive) must be
    stale-pong killed exactly once — the latch satellite — and then
    restarted by the supervisor."""
    faults = FaultInjector().mute_pings_after(1, slot=0, start_n=0)
    router = FleetRouter(tiny_engine, n_replicas=2, max_restarts=2,
                         restart_backoff_base_s=0.1,
                         health_interval_s=0.1, stale_after_s=1.0,
                         fault_injector=faults)
    try:
        router.start()
        assert _wait(
            lambda: router.counters["stale_pong_kills"] >= 1
            and router.status()["supervisor"]["restarts"] >= 1
            and router.status()["healthy_replicas"] == 2,
            timeout_s=120.0)
        st = router.status()
        # the monitor re-checks staleness every 0.1s tick while the EOF
        # lands — without the latch this would count dozens of kills
        assert st["counters"]["stale_pong_kills"] == 1
        # and the router-side counter merges into the fleet wire format
        assert router.fleet_metrics().merged().stale_pong_kills == 1
    finally:
        router.shutdown(drain=False)


def test_restart_succeeds_after_one_boot_failure():
    """Supervisor rides through an injected boot failure: the first
    restart attempt dies at boot, the second serves — and the work
    parked while nobody was healthy completes."""
    faults = (FaultInjector()
              .kill_after_submits(1, slot=0, start_n=0)
              .fail_boot(slot=0, start_n=1))
    router = FleetRouter(tiny_engine, n_replicas=1, max_restarts=3,
                         restart_backoff_base_s=0.1,
                         health_interval_s=0.1, fault_injector=faults)
    try:
        router.start()
        futs = [router.submit(r) for r in _requests(2)]
        outs = [f.result(timeout=300.0) for f in futs]
        st = router.status()
    finally:
        router.shutdown(drain=False)
    assert sorted(o.request_id for o in outs) == [0, 1]
    sup = st["supervisor"]
    assert sup["boot_failures"] >= 1
    assert sup["restarts"] >= 1
    assert sup["replicas_retired"] == 0
    assert st["replicas"][0]["start_n"] == 2   # third incarnation serves


def test_crash_loop_retires_slot_and_fails_parked_work():
    """Every incarnation dies on its first submit: the slot must be
    permanently retired after ``max_restarts`` and the unplaceable
    request failed — not requeued forever."""
    faults = FaultInjector().kill_after_submits(1, slot=0)  # every boot
    router = FleetRouter(tiny_engine, n_replicas=1, max_restarts=1,
                         retry_budget=10,     # keep quarantine out of it
                         restart_backoff_base_s=0.1,
                         health_interval_s=0.1, fault_injector=faults)
    try:
        router.start()
        fut = router.submit(DiffusionRequest(request_id=0, seed=0))
        with pytest.raises(RuntimeError, match="no recovery possible"):
            fut.result(timeout=300.0)
        assert _wait(lambda: router.status()["supervisor"][
            "replicas_retired"] == 1, timeout_s=30.0)
        st = router.status()
    finally:
        router.shutdown(drain=False)
    assert st["healthy_replicas"] == 0
    assert st["supervisor"]["retired_slots"] == [0]
    assert st["counters"]["poison_quarantined"] == 0


def test_poison_is_quarantined_healthy_traffic_unaffected():
    """A request that kills every replica it reaches must end in
    ``PoisonRequestError`` after its retry budget — while healthy
    requests sharing the fleet (including its own crash cohorts) all
    complete."""
    poison_rid = 99
    faults = FaultInjector().kill_on_request(poison_rid)   # all replicas
    router = FleetRouter(tiny_engine, n_replicas=2, max_restarts=4,
                         retry_budget=2, restart_backoff_base_s=0.1,
                         health_interval_s=0.1, fault_injector=faults)
    try:
        router.start()
        healthy = [router.submit(r) for r in _requests(6)]
        poison = router.submit(
            DiffusionRequest(request_id=poison_rid, seed=poison_rid))
        with pytest.raises(PoisonRequestError):
            poison.result(timeout=300.0)
        outs = [f.result(timeout=300.0) for f in healthy]  # untouched
        st = router.status()
    finally:
        router.shutdown(drain=False)
    assert sorted(o.request_id for o in outs) == list(range(6))
    rt = st["counters"]
    assert rt["poison_quarantined"] == 1
    assert rt["failed"] == 1                 # ONLY the poison request
    assert rt["replicas_lost"] >= 2          # it killed more than one


def test_sigkill_during_active_drain():
    """A replica SIGKILLed while ``drain()`` is blocked mid-flush: the
    drain must ride the requeue and still complete, every future
    resolving exactly once."""
    n = 12
    router = FleetRouter(tiny_engine, n_replicas=2, health_interval_s=0.1)
    try:
        router.start()
        futs = [router.submit(r) for r in _requests(n)]
        with router._lock:
            victim = max(router.replicas, key=lambda r: len(r.inflight))
            assert victim.inflight

        def killer():
            time.sleep(0.3)                  # let drain() start waiting
            victim.proc.kill()

        th = threading.Thread(target=killer, daemon=True)
        th.start()
        assert router.drain(timeout=300.0)   # survives the mid-drain kill
        th.join(5.0)
        outs = [f.result(timeout=10.0) for f in futs]
        st = router.status()
    finally:
        router.shutdown(drain=False)
    assert sorted(o.request_id for o in outs) == list(range(n))
    rt = st["counters"]
    assert rt["resolved"] == n and rt["failed"] == 0
    assert rt["duplicate_results"] == 0
