"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over
shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frequency
from repro.kernels import dct as dct_kernel
from repro.kernels import freqca_fused, ops, ref, ssd_scan


@pytest.mark.parametrize("s,d", [(64, 32), (128, 128), (256, 64),
                                 (512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dct_kernel_matches_ref(s, d, dtype):
    x = jax.random.normal(jax.random.key(0), (2, s, d)).astype(dtype)
    basis = frequency.dct_basis(s)
    y = dct_kernel.token_basis_matmul(basis, x, block_s=64, block_d=32,
                                      block_k=64)
    y_ref = ref.token_basis_matmul_ref(basis, x)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


@pytest.mark.pallas
@pytest.mark.parametrize("method", ["dct", "fft"])
@pytest.mark.parametrize("s,rho", [(64, 0.0625), (128, 0.125), (256, 0.25)])
def test_band_split_kernel_matches_decompose(method, s, rho):
    x = jax.random.normal(jax.random.key(1), (2, s, 32))
    low, high = dct_kernel.band_split(x, rho, method)
    low_r, high_r = ref.band_split_ref(x, rho, method)
    np.testing.assert_allclose(np.asarray(low), np.asarray(low_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(high), np.asarray(high_r),
                               atol=5e-5)


@pytest.mark.pallas
@pytest.mark.parametrize("method", ["dct", "fft", "none"])
@pytest.mark.parametrize("s,rho", [(64, 0.0625), (128, 0.125), (256, 0.25)])
def test_band_split_spectral_matches_decompose(method, s, rho):
    """Fused (low_spec, high) kernel vs the pure decompose oracle: the
    synthesised low band and the high residual must both match, and
    low + high must still reconstruct the input."""
    x = jax.random.normal(jax.random.key(21), (2, s, 32))
    low_spec, high = dct_kernel.band_split_spectral(x, rho, method)
    assert low_spec.shape == (2, frequency.spectral_kept_bins(s, rho,
                                                              method), 32)
    bands = frequency.decompose(x, rho, method)
    basis = frequency.low_band_basis(s, rho, method)
    low = jnp.einsum("ms,bmd->bsd", basis, low_spec)
    np.testing.assert_allclose(np.asarray(low), np.asarray(bands.low),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(high), np.asarray(bands.high),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(low + high), np.asarray(x),
                               atol=5e-5)


@pytest.mark.pallas
def test_band_split_spectral_kernel_matches_ref():
    """Pallas kernel vs the jnp twin the XLA dispatch path runs."""
    x = jax.random.normal(jax.random.key(22), (2, 128, 64))
    for method in ("dct", "fft"):
        lk, hk = dct_kernel.band_split_spectral(x, 0.0625, method)
        lr, hr = ref.band_split_spectral_ref(x, 0.0625, method)
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lr),
                                   atol=5e-5)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   atol=5e-5)


def test_band_split_projection_idempotent():
    """L is a projection: L(Lx) == Lx (kernel-level invariant)."""
    x = jax.random.normal(jax.random.key(2), (1, 128, 16))
    low, _ = dct_kernel.band_split(x, 0.125, "dct")
    low2, _ = dct_kernel.band_split(low, 0.125, "dct")
    np.testing.assert_allclose(np.asarray(low2), np.asarray(low), atol=5e-5)


@pytest.mark.parametrize("k,order", [(2, 1), (3, 2), (4, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_predict_matches_ref(k, order, dtype):
    low = jax.random.normal(jax.random.key(3), (2, 128, 64)).astype(dtype)
    hist = jax.random.normal(jax.random.key(4), (k, 2, 128, 64)).astype(dtype)
    ts = jnp.linspace(1.0, 0.5, k)
    y = freqca_fused.freqca_predict_fused(low, hist, ts, 0.3, order,
                                          block_s=64, block_d=64)
    y_ref = ref.freqca_predict_ref(low, hist, ts, 0.3, order)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol,
                               rtol=rtol)


def test_fused_weights_equal_full_solve():
    """w = B G^{-1} b_q folding == explicit coefficient fit + eval."""
    from repro.core import hermite
    ts = jnp.array([1.0, 0.7, 0.4])
    vals = jax.random.normal(jax.random.key(5), (3, 8, 8))
    w = freqca_fused.hermite_eval_weights(ts, 0.2, 2)
    folded = jnp.einsum("k,k...->...", w, vals)
    direct = hermite.predict(ts, vals, 0.2, 2)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(direct),
                               atol=1e-4)
    # fit_coefficients (solve-based, satellite bugfix) agrees with the
    # folded evaluation on multi-dim AND 1-d feature shapes
    coeffs = hermite.fit_coefficients(ts, vals, 2)
    via_fit = hermite.predict_from_coeffs(coeffs, ts, 0.2, 2)
    np.testing.assert_allclose(np.asarray(via_fit), np.asarray(direct),
                               atol=1e-4)
    c1 = hermite.fit_coefficients(ts, vals[:, 0, 0], 2)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(coeffs[:, 0, 0]),
                               atol=1e-5)


@pytest.mark.pallas
@pytest.mark.parametrize("k,order", [(3, 2), (4, 2)])
def test_fused_spectral_predict_matches_ring(k, order):
    """Extended fused kernel (spectral low + synthesis basis + per-lane
    folded weights over the slot-ordered ring) vs ring_predict + add."""
    from repro.core.policies import base as policy_base
    s, d, rho, b = 64, 32, 0.125, 2
    ring = policy_base.ring_init(b, k, (s, d))
    rng = jax.random.key(30)
    # push k+1 values so the ring head wraps (slot order != recency)
    for i, t in enumerate(jnp.linspace(1.0, 0.4, k + 1)):
        rng, sub = jax.random.split(rng)
        ring = policy_base.ring_push(
            ring, jax.random.normal(sub, (b, s, d)), t)
    basis = frequency.low_band_basis(s, rho, "dct")
    low_spec = jax.random.normal(jax.random.key(31), (b, basis.shape[0], d))
    w = policy_base.ring_slot_weights(ring, 0.3, order)
    y = freqca_fused.freqca_predict_fused_spectral(
        low_spec, basis.T, ring.vals, w, block_s=32, block_d=32)
    want = (jnp.einsum("sm,bmd->bsd", basis.T, low_spec)
            + policy_base.ring_predict(ring, 0.3, order))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (128, 32),
                                     (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_naive(s, chunk, dtype):
    b, h, p, n = 2, 2, 16, 8
    xs = (jax.random.normal(jax.random.key(6), (b, s, h, p)) * 0.5)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(7), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.key(8), (h,)) * 0.3)
    B = jax.random.normal(jax.random.key(9), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.key(10), (b, s, n)) * 0.5
    y = ssd_scan.ssd_chunk_scan(xs.astype(dtype), dt, A, B, C, chunk)
    y_ref, _ = ref.ssd_naive_ref(xs, dt, A, B, C)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


def test_ops_wrappers_jit():
    x = jax.random.normal(jax.random.key(0), (1, 128, 32))
    y = ops.dct_tokens(x)
    assert y.shape == x.shape
    lo, hi = ops.band_split(x, 0.125, "dct")
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(x), atol=1e-5)


def test_ops_backend_read_lazily(monkeypatch):
    """Satellite: dispatch must honour REPRO_KERNELS flips without a
    module reimport (INTERPRET was frozen at import time before)."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    assert ops.backend() in ("pallas", "xla")
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert ops.backend() == "pallas" and ops.use_pallas()
    monkeypatch.setenv("REPRO_KERNELS", "xla")
    assert ops.backend() == "xla" and not ops.use_pallas()
    monkeypatch.setenv("REPRO_KERNELS", "cuda")
    with pytest.raises(ValueError):
        ops.backend()
    # INTERPRET is a lazy attribute now, driven by the env override
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "0")
    assert ops.INTERPRET is False
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "1")
    assert ops.INTERPRET is True


@pytest.mark.pallas
def test_ops_band_split_spectral_backends_agree(monkeypatch):
    """The same call routed through both backends returns the same
    split (the pallas jits carry interpret/backend as static args, so
    flipping the env between calls cannot serve a stale executable)."""
    x = jax.random.normal(jax.random.key(40), (2, 128, 64))
    outs = {}
    for be in ("xla", "pallas"):
        monkeypatch.setenv("REPRO_KERNELS", be)
        outs[be] = ops.band_split_spectral(x, 0.125, "dct")
    for a, b in zip(outs["xla"], outs["pallas"], strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


@pytest.mark.pallas
@pytest.mark.parametrize("s,hq,hkv", [(64, 4, 2), (128, 8, 8), (64, 6, 2)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                           (False, 0)])
def test_flash_attention_matches_sdpa(s, hq, hkv, causal, window):
    from repro.kernels import flash_attention as fa
    from repro.models import attention as A
    b, hd = 2, 16
    q = jax.random.normal(jax.random.key(11), (b, s, hq, hd))
    k = jax.random.normal(jax.random.key(12), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.key(13), (b, s, hkv, hd))
    if causal:
        mask = A.causal_mask(s, window=window)
    else:
        mask = jnp.ones((1, s, s), bool)
    ref_out = A._sdpa(q, k, v, mask, hq // hkv)
    out = fa.flash_attention(q, k, v, hq // hkv, causal=causal,
                             window=window, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=5e-5)


@pytest.mark.pallas
def test_dit_joint_attention_flash_routing(monkeypatch):
    """models.dit routes joint attention to the flash kernel above the
    threshold under REPRO_KERNELS=pallas — outputs must match the
    full-logits einsum path."""
    from repro.models import dit
    b, s, nh, hd = 1, 128, 2, 16
    q = jax.random.normal(jax.random.key(50), (b, s, nh, hd))
    k = jax.random.normal(jax.random.key(51), (b, s, nh, hd))
    v = jax.random.normal(jax.random.key(52), (b, s, nh, hd))
    p_out = jax.random.normal(jax.random.key(53), (nh, hd, nh * hd)) * 0.1
    monkeypatch.setenv("REPRO_KERNELS", "xla")
    want = dit._joint_attention(q, k, v, p_out, jnp.float32)
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    monkeypatch.setattr(dit, "_FLASH_MIN_SEQ", 64)
    got = dit._joint_attention(q, k, v, p_out, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)
    # below the threshold the einsum path serves even under pallas
    monkeypatch.setattr(dit, "_FLASH_MIN_SEQ", 4096)
    assert not dit._flash_ok(s)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels import flash_attention as fa
    from repro.models import attention as A
    b, s, hq, hkv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(jax.random.key(1), (b, s, hq, hd)).astype(dtype)
    k = jax.random.normal(jax.random.key(2), (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.key(3), (b, s, hkv, hd)).astype(dtype)
    ref_out = A._sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), A.causal_mask(s), hq // hkv)
    out = fa.flash_attention(q, k, v, hq // hkv, q_block=32, kv_block=32)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out), atol=atol)
