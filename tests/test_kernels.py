"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over
shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frequency
from repro.kernels import dct as dct_kernel
from repro.kernels import freqca_fused, ops, ref, ssd_scan


@pytest.mark.parametrize("s,d", [(64, 32), (128, 128), (256, 64),
                                 (512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dct_kernel_matches_ref(s, d, dtype):
    x = jax.random.normal(jax.random.key(0), (2, s, d)).astype(dtype)
    basis = frequency.dct_basis(s)
    y = dct_kernel.token_basis_matmul(basis, x, block_s=64, block_d=32,
                                      block_k=64)
    y_ref = ref.token_basis_matmul_ref(basis, x)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


@pytest.mark.parametrize("method", ["dct", "fft"])
@pytest.mark.parametrize("s,rho", [(64, 0.0625), (128, 0.125), (256, 0.25)])
def test_band_split_kernel_matches_decompose(method, s, rho):
    x = jax.random.normal(jax.random.key(1), (2, s, 32))
    low, high = dct_kernel.band_split(x, rho, method)
    low_r, high_r = ref.band_split_ref(x, rho, method)
    np.testing.assert_allclose(np.asarray(low), np.asarray(low_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(high), np.asarray(high_r),
                               atol=5e-5)


def test_band_split_projection_idempotent():
    """L is a projection: L(Lx) == Lx (kernel-level invariant)."""
    x = jax.random.normal(jax.random.key(2), (1, 128, 16))
    low, _ = dct_kernel.band_split(x, 0.125, "dct")
    low2, _ = dct_kernel.band_split(low, 0.125, "dct")
    np.testing.assert_allclose(np.asarray(low2), np.asarray(low), atol=5e-5)


@pytest.mark.parametrize("k,order", [(2, 1), (3, 2), (4, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_predict_matches_ref(k, order, dtype):
    low = jax.random.normal(jax.random.key(3), (2, 128, 64)).astype(dtype)
    hist = jax.random.normal(jax.random.key(4), (k, 2, 128, 64)).astype(dtype)
    ts = jnp.linspace(1.0, 0.5, k)
    y = freqca_fused.freqca_predict_fused(low, hist, ts, 0.3, order,
                                          block_s=64, block_d=64)
    y_ref = ref.freqca_predict_ref(low, hist, ts, 0.3, order)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol,
                               rtol=rtol)


def test_fused_weights_equal_full_solve():
    """w = B G^{-1} b_q folding == explicit coefficient fit + eval."""
    from repro.core import hermite
    ts = jnp.array([1.0, 0.7, 0.4])
    vals = jax.random.normal(jax.random.key(5), (3, 8, 8))
    w = freqca_fused.hermite_eval_weights(ts, 0.2, 2)
    folded = jnp.einsum("k,k...->...", w, vals)
    direct = hermite.predict(ts, vals, 0.2, 2)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(direct),
                               atol=1e-4)


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (128, 32),
                                     (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_naive(s, chunk, dtype):
    b, h, p, n = 2, 2, 16, 8
    xs = (jax.random.normal(jax.random.key(6), (b, s, h, p)) * 0.5)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(7), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.key(8), (h,)) * 0.3)
    B = jax.random.normal(jax.random.key(9), (b, s, n)) * 0.5
    C = jax.random.normal(jax.random.key(10), (b, s, n)) * 0.5
    y = ssd_scan.ssd_chunk_scan(xs.astype(dtype), dt, A, B, C, chunk)
    y_ref, _ = ref.ssd_naive_ref(xs, dt, A, B, C)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


def test_ops_wrappers_jit():
    x = jax.random.normal(jax.random.key(0), (1, 128, 32))
    y = ops.dct_tokens(x)
    assert y.shape == x.shape
    lo, hi = ops.band_split(x, 0.125, "dct")
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(x), atol=1e-5)


@pytest.mark.parametrize("s,hq,hkv", [(64, 4, 2), (128, 8, 8), (64, 6, 2)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                           (False, 0)])
def test_flash_attention_matches_sdpa(s, hq, hkv, causal, window):
    from repro.kernels import flash_attention as fa
    from repro.models import attention as A
    b, hd = 2, 16
    q = jax.random.normal(jax.random.key(11), (b, s, hq, hd))
    k = jax.random.normal(jax.random.key(12), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.key(13), (b, s, hkv, hd))
    if causal:
        mask = A.causal_mask(s, window=window)
    else:
        mask = jnp.ones((1, s, s), bool)
    ref_out = A._sdpa(q, k, v, mask, hq // hkv)
    out = fa.flash_attention(q, k, v, hq // hkv, causal=causal,
                             window=window, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels import flash_attention as fa
    from repro.models import attention as A
    b, s, hq, hkv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(jax.random.key(1), (b, s, hq, hd)).astype(dtype)
    k = jax.random.normal(jax.random.key(2), (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.key(3), (b, s, hkv, hd)).astype(dtype)
    ref_out = A._sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), A.causal_mask(s), hq // hkv)
    out = fa.flash_attention(q, k, v, hq // hkv, q_block=32, kv_block=32)
    atol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out), atol=atol)
