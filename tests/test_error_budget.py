"""Quality-SLO tests: error-budgeted activation (freqca_eb), budget
tiers, the per-request ``max_error`` path through scheduler + engine,
load shedding (relax, never drop), the deprecated ``CachePolicy``
shim, and the golden guarantee that requests without a budget are
bitwise-identical to the pre-SLO serving path (feedback stays a None
pytree, so non-SLO jit signatures are unchanged programs).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as config_lib
from repro.core import cache as cache_lib
from repro.core import policies
from repro.core.policies import base as policy_base
from repro.core.policies.freqca_eb import (ERROR_TIERS, FreqCaEbState,
                                           FreqCaErrorBudgetPolicy,
                                           budget_tier)
from repro.diffusion import sampler, schedule
from repro.serving.async_engine import AsyncDiffusionEngine
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.scheduler import Scheduler

SIZE = 8
N_STEPS = 6


@pytest.fixture(scope="module")
def dit_fns():
    from repro.models import common, dit
    cfg = config_lib.reduced(config_lib.get_config("dit-small"))
    params = common.init_params(dit.dit_specs(cfg), jax.random.key(0))

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, SIZE, SIZE)

    return cfg, full_fn, from_crf_fn


def make_engine(dit_fns, policy, max_batch=4, **kw):
    cfg, full_fn, from_crf_fn = dit_fns
    return DiffusionEngine(full_fn, from_crf_fn,
                           (SIZE, SIZE, cfg.in_channels),
                           (16, cfg.d_model), policy,
                           n_steps=N_STEPS, max_batch=max_batch, **kw)


# ---------------------------------------------------------------------------
# budget tiers / with_budget / compatibility keys
# ---------------------------------------------------------------------------

def test_budget_tier_snaps_down_never_up():
    assert budget_tier(0.015) == 0.01     # snap DOWN (more quality)
    assert budget_tier(0.1) == 0.1        # exact tier is itself
    assert budget_tier(0.35) == 0.2
    assert budget_tier(7.0) == 1.0        # above the ladder: loosest tier
    assert budget_tier(0.001) == 0.01     # below the ladder: strictest
    assert all(budget_tier(t) == t for t in ERROR_TIERS)


def test_with_budget_replaces_and_folds_into_key():
    pol = FreqCaErrorBudgetPolicy(method="dct", rho=0.25)
    assert pol.with_budget(None) is pol
    tight = pol.with_budget(0.011)
    assert tight.budget == 0.01
    assert tight is not pol
    key = policies.compatibility_key
    # distinct tiers are distinct groups/signatures; same tier collapses
    assert key(tight) != key(pol.with_budget(0.2))
    assert key(pol.with_budget(0.013)) == key(tight)
    # non-feedback policies ignore the budget (base default)
    fre = policies.FreqCaPolicy(interval=5)
    assert fre.with_budget(0.05) is fre


def test_spec_route_builds_eb_from_threshold():
    spec = cache_lib.CachePolicy(kind="freqca_eb", tea_threshold=0.3)
    pol = policies.resolve(spec)
    assert isinstance(pol, FreqCaErrorBudgetPolicy)
    assert pol.budget == budget_tier(0.3)


def test_cachepolicy_resolve_warns_exactly_once():
    cache_lib._RESOLVE_WARNED = False
    with pytest.warns(DeprecationWarning, match="deprecated"):
        cache_lib.CachePolicy(kind="freqca").resolve()
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # a second warn would raise
        pol = cache_lib.CachePolicy(kind="fora").resolve()
    assert pol == policies.ForaPolicy()


# ---------------------------------------------------------------------------
# deterministic budget accumulation (decide() is pure bookkeeping)
# ---------------------------------------------------------------------------

EB = FreqCaErrorBudgetPolicy(method="dct", rho=0.25, budget=0.1)


def _hot_state(batch=1, rate_low=0.03, rate_high=0.01):
    """Post-warm-up state with known band rates."""
    st = EB.init(batch, (4, 8))
    return st._replace(
        n_valid=jnp.full((batch,), EB.needed_history + 1, jnp.int32),
        rate_low=jnp.full((batch,), rate_low, jnp.float32),
        rate_high=jnp.full((batch,), rate_high, jnp.float32))


def test_budget_spend_and_carry_over():
    st = _hot_state()                      # rate = 0.04 / cached step
    st, act = EB.decide(st, None)
    assert not bool(act[0])
    assert st.acc[0] == pytest.approx(0.04)
    st, act = EB.decide(st, None)          # carry-over accumulates
    assert not bool(act[0])
    assert st.acc[0] == pytest.approx(0.08)
    assert st.peak[0] == pytest.approx(0.08)
    assert int(st.events[0]) == 0


def test_budget_event_triggers_and_resets():
    st = _hot_state()
    for _ in range(2):
        st, act = EB.decide(st, None)
    # third cached step would spend 0.12 > 0.1: full forward fires
    st, act = EB.decide(st, None)
    assert bool(act[0])
    assert st.acc[0] == pytest.approx(0.0)         # reset on full step
    assert int(st.events[0]) == 1
    # peak is the realized SLO: never exceeds the budget by construction
    assert st.peak[0] == pytest.approx(0.08)
    assert float(st.peak[0]) <= EB.budget


def test_rate_above_budget_means_every_step_full():
    st = _hot_state(rate_low=0.2, rate_high=0.05)
    for i in range(3):
        st, act = EB.decide(st, None)
        assert bool(act[0])
        assert int(st.events[0]) == i + 1
    assert st.peak[0] == pytest.approx(0.0)


def test_warmup_fulls_are_not_budget_events():
    st = EB.init(1, (4, 8))                # n_valid = 0: warm
    st = st._replace(rate_low=jnp.full((1,), 9.9, jnp.float32))
    st, act = EB.decide(st, None)
    assert bool(act[0])
    assert int(st.events[0]) == 0          # warm full, not an event
    # one calibration full beyond the predictor's warm-up
    st = st._replace(n_valid=jnp.full((1,), EB.needed_history, jnp.int32))
    _, act = EB.decide(st, None)
    assert bool(act[0])


def test_lanes_spend_independently():
    st = _hot_state(batch=2)
    st = st._replace(rate_low=jnp.array([0.03, 0.2], jnp.float32))
    st, act = EB.decide(st, None)
    assert not bool(act[0]) and bool(act[1])
    assert st.acc[0] == pytest.approx(0.04)
    assert int(st.events[0]) == 0 and int(st.events[1]) == 1


def test_observe_updates_band_rates():
    st = EB.init(2, (4, 8))
    err = jnp.array([[0.01, 0.02], [0.3, 0.4]], jnp.float32)
    st = EB.observe(st, err, None)
    np.testing.assert_allclose(np.asarray(st.rate_low), [0.01, 0.3])
    np.testing.assert_allclose(np.asarray(st.rate_high), [0.02, 0.4])
    fb = EB.error_feedback(st)
    assert isinstance(fb, policy_base.ErrorFeedback)
    assert fb.realized.shape == (2,) and fb.events.shape == (2,)


def test_state_bytes_count_feedback_scalars():
    batch = 4
    fre = policies.FreqCaPolicy(method="dct", rho=0.25, high_order=2)
    eb = FreqCaErrorBudgetPolicy(method="dct", rho=0.25, high_order=2)
    d = (eb.state_bytes(eb.init(batch, (16, 32)))
         - fre.state_bytes(fre.init(batch, (16, 32))))
    # two band rates + accumulator + peak (f32) + event count (i32)
    assert d == batch * 5 * 4


# ---------------------------------------------------------------------------
# end-to-end on synthetic rough dynamics (deterministic, no model)
# ---------------------------------------------------------------------------

def _rough_fns(s=4, d=8, size=4, ch=2, amp=0.3, freq=8.0):
    """CRF oscillates fast in t, so Hermite forecasts err at a rate the
    budget can meter.  s*d must equal size*size*ch."""
    def full_fn(x, t):
        crf = jnp.tanh(x.reshape(x.shape[0], s, d))
        crf = crf + amp * jnp.sin(freq * t)
        return crf.reshape(x.shape) * 0.1, crf

    def from_crf_fn(crf, t):
        return crf.reshape(crf.shape[0], size, size, ch) * 0.1

    return full_fn, from_crf_fn


def _run_eb(budget, n_steps=40):
    full_fn, from_crf_fn = _rough_fns()
    x0 = jax.random.normal(jax.random.key(3), (2, 4, 4, 2))
    pol = FreqCaErrorBudgetPolicy(method="dct", rho=0.25).with_budget(budget)
    return sampler.sample(full_fn, from_crf_fn, x0,
                          schedule.timesteps(n_steps), pol,
                          crf_shape=(2, 4, 8))


def test_eb_realized_error_respects_budget():
    for budget in (0.02, 0.1, 0.5):
        res = _run_eb(budget)
        assert res.feedback is not None
        assert float(jnp.max(res.feedback.realized)) <= budget + 1e-6


def test_eb_tighter_budget_means_more_fulls():
    fulls = [int(_run_eb(b).n_full) for b in (0.02, 0.1, 0.5)]
    assert fulls == sorted(fulls, reverse=True), fulls
    assert fulls[0] > fulls[-1], fulls     # budgets actually differentiate
    res = _run_eb(0.02)
    assert int(jnp.sum(res.feedback.events)) > 0


def test_non_feedback_policies_report_no_feedback():
    full_fn, from_crf_fn = _rough_fns()
    x0 = jax.random.normal(jax.random.key(3), (2, 4, 4, 2))
    for pol in (policies.NoCachePolicy(),
                policies.FreqCaPolicy(interval=3, method="dct", rho=0.25),
                policies.ForaPolicy(interval=2),
                policies.FreqCaAdaptivePolicy(method="dct", rho=0.25,
                                              tea_threshold=0.3)):
        res = sampler.sample(full_fn, from_crf_fn, x0,
                             schedule.timesteps(12), pol,
                             crf_shape=(2, 4, 8))
        assert res.feedback is None, pol


# ---------------------------------------------------------------------------
# load shedding: relax budgets under queue pressure, never drop
# ---------------------------------------------------------------------------

def test_shed_relaxes_effective_budget_never_drops():
    eb = FreqCaErrorBudgetPolicy(method="dct", rho=0.25)
    sched = Scheduler(max_batch=4, default_policy=eb, shed_depth=2,
                      shed_factor=4.0, group_policies=True,
                      clock=lambda: 0.0)
    reqs = [DiffusionRequest(request_id=i, seed=i, max_error=0.05)
            for i in range(4)]
    for r in reqs:
        sched.submit(r, now=0.0)
    # below shed depth: budget honored; at/over: relaxed, not dropped
    assert reqs[0].effective_max_error == 0.05
    assert reqs[1].effective_max_error == 0.05
    assert reqs[2].effective_max_error == pytest.approx(0.2)
    assert reqs[3].effective_max_error == pytest.approx(0.2)
    assert sched.shed_events == 2
    tiers = {sched.effective_policy(r).budget for r in reqs}
    assert tiers == {budget_tier(0.05), budget_tier(0.2)}
    served = []
    while len(sched):
        plan = sched.form_batch(now=0.0, flush=True)
        served += [r.request_id for r in plan.requests]
        # every cut is budget-tier pure (tier folds into the group key)
        assert len({sched.effective_policy(r).budget
                    for r in plan.requests}) == 1
    assert sorted(served) == [0, 1, 2, 3]  # relaxed, NEVER dropped


def test_no_shed_below_depth_and_no_budget_requests_untouched():
    eb = FreqCaErrorBudgetPolicy(method="dct", rho=0.25)
    sched = Scheduler(max_batch=8, default_policy=eb, shed_depth=100,
                      shed_factor=4.0, clock=lambda: 0.0)
    a = DiffusionRequest(request_id=0, seed=0, max_error=0.05)
    b = DiffusionRequest(request_id=1, seed=1)          # no SLO
    sched.submit(a, now=0.0)
    sched.submit(b, now=0.0)
    assert a.effective_max_error == 0.05
    assert b.effective_max_error is None
    assert sched.shed_events == 0
    assert sched.effective_policy(b) == eb              # default untouched


# ---------------------------------------------------------------------------
# engine: SLO report + golden no-budget path
# ---------------------------------------------------------------------------

def test_engine_reports_realized_error_and_metrics(dit_fns):
    eb = FreqCaErrorBudgetPolicy(method="dct", rho=0.25)
    eng = make_engine(dit_fns, eb)
    reqs = [DiffusionRequest(request_id=i, seed=i, max_error=0.1)
            for i in range(3)]
    outs = eng.run_batch(reqs=reqs, now=0.0)
    assert len(outs) == 3
    for o in outs:
        assert o.realized_error is not None
        assert o.realized_error <= budget_tier(0.1) + 1e-6
        assert isinstance(o.budget_events, int)
    s = eng.metrics.summary()
    assert s["realized_error_p95"] is not None
    assert s["realized_error_p95"] <= budget_tier(0.1) + 1e-6
    assert s["budget_events"] == sum(o.budget_events for o in outs)
    assert s["shed_events"] == 0
    (group,) = s["per_group"].values()
    assert "budget_events" in group and "realized_error_p95" in group
    snap = eng.metrics.snapshot().summary()   # snapshot carries SLO state
    assert snap["realized_error_p95"] == s["realized_error_p95"]


def test_run_batch_reqs_equals_submit_then_run(dit_fns):
    eb = FreqCaErrorBudgetPolicy(method="dct", rho=0.25)
    reqs = lambda: [DiffusionRequest(request_id=i, seed=i, max_error=0.05)
                    for i in range(2)]
    eng_a = make_engine(dit_fns, eb)
    out_a = eng_a.run_batch(reqs=reqs(), now=0.0)
    eng_b = make_engine(dit_fns, eb)
    for r in reqs():
        eng_b.submit(r, now=0.0)
    out_b = eng_b.run_batch(now=0.0)
    for a, b in zip(out_a, out_b, strict=True):
        np.testing.assert_array_equal(np.asarray(a.latents),
                                      np.asarray(b.latents))
        assert a.realized_error == b.realized_error


def test_no_budget_requests_are_bitwise_pre_slo(dit_fns):
    """max_error=None must leave the serving path untouched: same
    results with or without the shedding config, across grouped /
    ungrouped / async submission, and no SLO fields reported."""
    fre = policies.FreqCaPolicy(interval=3)

    def reqs():
        return [DiffusionRequest(request_id=i, seed=i, max_error=None)
                for i in range(4)]

    golden = make_engine(dit_fns, fre).run_batch(reqs=reqs(), now=0.0)
    assert all(o.realized_error is None and o.budget_events is None
               for o in golden)
    variants = [
        make_engine(dit_fns, fre, shed_depth=1, shed_factor=8.0),
        make_engine(dit_fns, fre, group_policies=False),
    ]
    for eng in variants:
        outs = eng.run_batch(reqs=reqs(), now=0.0)
        for g, o in zip(golden, outs, strict=True):
            np.testing.assert_array_equal(np.asarray(g.latents),
                                          np.asarray(o.latents))
            assert o.realized_error is None
    # async submit path: same request type, same bitwise results
    aeng_inner = make_engine(dit_fns, fre)
    with AsyncDiffusionEngine(aeng_inner) as aeng:
        futs = [aeng.submit(r) for r in reqs()]
        outs = {f.result().request_id: f.result() for f in futs}
    for g in golden:
        np.testing.assert_array_equal(
            np.asarray(g.latents), np.asarray(outs[g.request_id].latents))
    s = aeng_inner.metrics.summary()
    assert s["realized_error_p95"] is None and s["budget_events"] == 0
