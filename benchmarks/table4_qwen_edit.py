"""Paper Table 4 (Qwen-Image-Edit) at CPU scale — editing grid with FFT
decomposition (the paper's Qwen-Edit setting)."""
from benchmarks import table3_kontext


def main():
    table3_kontext.run(method="fft",
                       title="Table 4 — Qwen-Image-Edit-like (FFT)",
                       out="results/bench/table4.json")


if __name__ == "__main__":
    main()
