"""Multi-resolution serving: one engine (and a 2-replica fleet) over a
mixed-shape Poisson stream.

One deployment declares a three-entry shape ladder (half / primary /
double image size — e.g. 64/256/1024 tokens at the default bench
scale) and serves a mixed-resolution Poisson arrival stream through
the (batch-bucket, shape-bucket) signature path:

* **multires_poisson** — open-loop replay through the single warmed
  engine.  Asserted: zero steady-state recompiles, every cut
  shape-pure (checked on every ``execute_plan`` call), compiled
  signatures <= shapes x groups x buckets (``signature_budget``), and
  a submit carrying an undeclared shape rejected with
  ``ShapeMismatchError`` before it touches the queue.
* **multires_fleet** — the same plan through a ``FleetRouter`` over 2
  replicas, each warming the full ladder.  Asserted: nothing dropped,
  ``submitted == resolved + failed`` (a bad-shape submit through the
  router fails fast and leaves the counters in step), zero
  steady-state recompiles on every replica.
* **multires_closed vs three_singles** — closed-loop drain of the
  mixed stream through the one multi-shape engine vs the sum of three
  single-shape engines each draining its own sub-stream (the
  deployment the shape ladder replaces).  The req/s ratio is recorded
  (not hard-asserted: it measures consolidation overhead, which is
  host-dependent), the executable counts are.

Emits ``results/bench/BENCH_serve_multires.json``.  Run directly
(``python -m benchmarks.serve_multires``) or via
``benchmarks/run.py --smoke``; the ``__main__`` guard is mandatory —
the spawn start method re-imports this module in every fleet worker.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax.numpy as jnp

from benchmarks import common as B
from repro.core.policies import FreqCaPolicy
from repro.launch.serve import poisson_stream, serve_fleet_open_loop, \
    serve_open_loop
from repro.models import dit
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.fleet import FleetRouter
from repro.serving.scheduler import ShapeMismatchError


def ladder_sizes():
    """Half / primary / double the bench image size."""
    s = B.img_size()
    return (s // 2, s, 2 * s)


def shape_pairs(cfg, sizes):
    return [((s, s, cfg.in_channels),
             ((s // cfg.patch_size) ** 2, cfg.d_model)) for s in sizes]


def multires_engine(max_batch: int, interval: int, max_wait_s: float,
                    sizes=None):
    """Worker-side engine builder — module-level so its
    ``functools.partial`` pickles under spawn.  ``from_crf_fn`` is
    shape-generic (image side recovered from the token count), so one
    callable serves the whole ladder."""
    cfg, params = B.get_model()

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        side = int(round(crf.shape[1] ** 0.5)) * cfg.patch_size
        return dit.dit_from_crf(params, crf, tb, cfg, side, side)

    sizes = list(sizes) if sizes else [B.img_size()]
    pairs = shape_pairs(cfg, sizes)
    return DiffusionEngine(full_fn, from_crf_fn, pairs[0][0], pairs[0][1],
                           FreqCaPolicy(interval=interval, method="dct"),
                           n_steps=B.N_STEPS, max_batch=max_batch,
                           max_wait_s=max_wait_s, shapes=pairs[1:])


def _count_pure_cuts(eng):
    """Wrap ``execute_plan`` to assert every cut is shape-pure (all
    lanes resolve to one shape key) and count the cuts."""
    counter = [0]
    orig = eng.execute_plan

    def checked(plan):
        cut_shapes = {eng.scheduler.shape_of(r) for r in plan.requests}
        assert len(cut_shapes) == 1, f"mixed-shape cut: {cut_shapes}"
        counter[0] += 1
        return orig(plan)

    eng.execute_plan = checked
    return counter


def run(out: str = "results/bench/BENCH_serve_multires.json",
        n_requests: int = 18, max_batch: int = 4, interval: int = 5,
        title: str = "Multi-resolution serving — one (batch, shape) "
                     "bucketed engine"):
    cfg, _ = B.get_model()
    sizes = ladder_sizes()
    pairs = shape_pairs(cfg, sizes)
    rows = []

    # --- leg 1: one engine, mixed-shape Poisson stream ------------------
    eng = multires_engine(max_batch, interval, 0.02, sizes=sizes)
    eng.warmup()
    budget = eng.signature_budget()
    warm_sigs = eng.compiled_buckets()

    # capacity probe (primary shape): sets an arrival rate the engine
    # can sustain without the open-loop replay dragging on for minutes
    t0 = time.perf_counter()
    for i in range(max_batch):
        eng.submit(DiffusionRequest(request_id=10_000 + i, seed=i))
    eng.serve_until_drained()
    rate = 2.0 * max_batch / max(time.perf_counter() - t0, 1e-9)

    pre = eng.metrics_dict()["compile_misses"]
    pure_cuts = _count_pure_cuts(eng)
    plan = poisson_stream(n_requests, rate, B.img_size(), cfg.in_channels,
                          edit_every=0, shapes=pairs)
    outs, wall = serve_open_loop(eng, plan)
    steady = eng.metrics_dict()["compile_misses"] - pre

    # bad-shape submit: rejected at the API boundary, queue untouched
    bad = DiffusionRequest(request_id=-1, seed=0,
                           latent_shape=(B.img_size() + 2,) * 2
                           + (cfg.in_channels,))
    try:
        eng.submit(bad)
        bad_rejected = False
    except ShapeMismatchError:
        bad_rejected = eng.scheduler.depth == 0

    served_shapes = {}
    for o in outs:
        k = tuple(o.latents.shape)
        served_shapes[k] = served_shapes.get(k, 0) + 1
    rows.append({
        "leg": "multires_poisson",
        "shapes": len(pairs),
        "submitted": n_requests,
        "served": len(outs),
        "dropped": n_requests - len(outs),
        "wall_s": round(wall, 3),
        "req_per_s": round(len(outs) / max(wall, 1e-9), 3),
        "shape_pure_cuts": pure_cuts[0],
        "steady_recompiles": steady,
        "compiled_signatures": eng.compiled_buckets(),
        "signature_budget": budget,
        "bad_shape_rejected": bad_rejected,
        "served_per_shape": {str(k): v for k, v in
                             sorted(served_shapes.items())},
    })

    # --- leg 2: closed-loop, one multi-shape engine vs three singles ----
    replay = [dataclasses.replace(r, arrival_s=0.0, submit_time=0.0)
              for r in plan]
    t0 = time.perf_counter()
    for r in replay:
        eng.submit(r)
    m_outs = eng.serve_until_drained()
    multires_wall = time.perf_counter() - t0

    singles_wall, singles_served, singles_sigs = 0.0, 0, 0
    for s, pair in zip(sizes, pairs, strict=True):
        se = multires_engine(max_batch, interval, 0.02, sizes=[s])
        se.warmup()
        singles_sigs += se.compiled_buckets()
        sub = [dataclasses.replace(r, arrival_s=0.0, submit_time=0.0)
               for r in plan if r.latent_shape == pair[0]]
        t0 = time.perf_counter()
        for r in sub:
            se.submit(r)
        singles_served += len(se.serve_until_drained())
        singles_wall += time.perf_counter() - t0
        del se
    m_rps = len(m_outs) / max(multires_wall, 1e-9)
    s_rps = singles_served / max(singles_wall, 1e-9)
    rows.append({
        "leg": "multires_closed_vs_singles",
        "shapes": len(pairs),
        "served_multires": len(m_outs),
        "served_singles": singles_served,
        "multires_wall_s": round(multires_wall, 3),
        "singles_wall_s": round(singles_wall, 3),
        "multires_req_per_s": round(m_rps, 3),
        "singles_req_per_s": round(s_rps, 3),
        "rps_vs_singles": round(m_rps / max(s_rps, 1e-9), 3),
        "multires_signatures": eng.compiled_buckets(),
        "singles_signatures_total": singles_sigs,
    })
    del eng

    # --- leg 3: 2-replica fleet, same mixed stream ----------------------
    factory = functools.partial(multires_engine, max_batch, interval,
                                0.02, sizes)
    router = FleetRouter(factory, n_replicas=2)
    try:
        router.start()
        fplan = [dataclasses.replace(r, submit_time=0.0) for r in plan]
        f_outs, f_wall = serve_fleet_open_loop(router, fplan, clients=4)
        # bad-shape submit through the router: synchronous rejection,
        # counters stay in step (submitted never incremented)
        try:
            router.submit(dataclasses.replace(bad))
            fleet_bad_rejected = False
        except ShapeMismatchError:
            fleet_bad_rejected = True
        fm = router.fleet_metrics()
        rt = router.status()["counters"]
    finally:
        router.shutdown(drain=True)
    s = fm.summary()
    fleet_steady = {idx: pr["steady_recompiles"]
                    for idx, pr in s["per_replica"].items()}
    rows.append({
        "leg": "multires_fleet",
        "replicas": 2,
        "shapes": len(pairs),
        "submitted": n_requests,
        "served": len(f_outs),
        "dropped": n_requests - len(f_outs),
        "unresolved": rt["submitted"] - rt["resolved"] - rt["failed"],
        "wall_s": round(f_wall, 3),
        "req_per_s": round(len(f_outs) / max(f_wall, 1e-9), 3),
        "steady_recompiles": fleet_steady,
        "bad_shape_rejected": fleet_bad_rejected,
        "shape_keys": s["fleet"].get("shape_keys", 0),
    })

    # rows carry per-leg schemas: one table per leg
    for r in rows:
        B.print_table(f"{title} — {r['leg']}",
                      [{k: v for k, v in r.items()
                        if not isinstance(v, dict)}])

    # hard invariants (the CI multires guard re-checks these from the
    # emitted json): compile-free steady state, bounded signatures,
    # shape-pure cuts, fail-fast validation, conservation
    poisson, closed, fleet = rows
    assert poisson["dropped"] == 0 and poisson["steady_recompiles"] == 0
    assert poisson["compiled_signatures"] <= poisson["signature_budget"]
    assert poisson["shape_pure_cuts"] > 0
    assert poisson["bad_shape_rejected"]
    assert len(poisson["served_per_shape"]) == len(pairs)
    assert closed["served_multires"] == n_requests
    assert closed["multires_signatures"] <= poisson["signature_budget"]
    assert fleet["dropped"] == 0 and fleet["unresolved"] == 0
    assert all(v == 0 for v in fleet["steady_recompiles"].values())
    assert fleet["bad_shape_rejected"]
    B.save_rows(out, rows)
    return rows


if __name__ == "__main__":
    run()
