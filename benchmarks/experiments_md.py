"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/dryrun/*.json and results/bench/*.json.

  PYTHONPATH=src python -m benchmarks.experiments_md > EXPERIMENTS_tables.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline as rl


def _fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_section(dryrun_dir="results/dryrun"):
    print("\n## §Dry-run (generated)\n")
    print("Per-device numbers from `compiled.memory_analysis()` and the "
          "trip-count-aware HLO analyzer; `coll_gb` = per-device "
          "collective bytes per step.\n")
    hdr = ("arch | shape | mesh | compile_s | args_gb/dev | temp_gb/dev | "
           "hlo_flops/dev | hlo_gb/dev | coll_gb/dev | top collective")
    print(hdr)
    print(" | ".join(["---"] * len(hdr.split(" | "))))
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(path))
        c = r["collectives"]
        kinds = {k: v for k, v in c.items()
                 if k not in ("total_bytes", "op_counts")}
        top = max(kinds, key=kinds.get) if kinds else "-"
        print(" | ".join([
            r["arch"], r["shape"], r["mesh"], str(r["compile_s"]),
            _fmt_bytes(r["memory"].get("argument_size_bytes", 0)),
            _fmt_bytes(r["memory"].get("temp_size_bytes", 0)),
            f"{r['flops']:.3e}",
            _fmt_bytes(r["bytes_accessed"]),
            _fmt_bytes(c.get("total_bytes", 0.0)),
            top,
        ]))


def roofline_section():
    print("\n## §Roofline (generated)\n")
    rows = rl.run(out="results/bench/roofline.json")
    # printed by rl.run already in markdown form


def main():
    dryrun_section()
    rows = rl.run(out="results/bench/roofline.json")


if __name__ == "__main__":
    main()
