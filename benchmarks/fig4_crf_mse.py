"""Paper Figure 4: prediction MSE — layer-wise caching vs CRF caching.

Runs the reference (uncached) trajectory, and at every predictable step
forecasts the model output feature two ways from the same K=3 history:
(a) layer-wise: predict each block's residual delta, sum them;
(b) CRF: predict the single cumulative residual feature directly.
Reports per-step MSE stats; the paper finds CRF within ~4% of layer-wise
while using ~1% of the memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as B
from repro.core import cache as cache_lib
from repro.core.cache import CachePolicy
from repro.diffusion import schedule
from repro.models import common as mcommon
from repro.models import dit


def forward_with_residuals(params, latents, t, cfg):
    """Unrolled dit forward returning (crf, per-layer residual deltas)."""
    b, h, w, c = latents.shape
    dtype = jnp.dtype(cfg.dtype)
    x = dit.patchify(latents.astype(dtype), cfg.patch_size)
    x = mcommon.dense(params["patch_proj"], x)
    x = x + dit._pos_embedding(x.shape[1], cfg.d_model).astype(dtype)[None]
    cond = dit._time_cond(params, t, cfg, dtype)
    deltas = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["single"])
        x_new = dit.single_block(lp, x, cond, cfg)
        deltas.append(x_new - x)
        x = x_new
    return x, jnp.stack(deltas)  # crf, [L, B, S, D]


def run(out: str = "results/bench/fig4.json", interval: int = 5):
    cfg, params = B.get_model()
    x = jax.random.normal(jax.random.key(9),
                          (2, B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels))
    ts = schedule.timesteps(B.N_STEPS)
    fwd = jax.jit(lambda lat, t: forward_with_residuals(
        params, lat, jnp.full((lat.shape[0],), t), cfg))
    full_fn, _ = B.make_fns(cfg, params)

    pol = CachePolicy(kind="taylorseer", high_order=2)
    feat = None
    lw_state = crf_state = None
    h0 = None
    mse_lw, mse_crf, e_ref = [], [], []
    for i in range(B.N_STEPS):
        t_now, t_next = float(ts[i]), float(ts[i + 1])
        crf, deltas = fwd(x, t_now)
        if feat is None:
            feat = crf.shape
            lw_state = cache_lib.layerwise_init(pol, cfg.n_layers, feat)
            crf_state = cache_lib.init_state(pol, feat)
            h0 = crf - deltas.sum(0)    # embedding+pos part (t-invariant)
        if int(crf_state.n_valid) >= 3 and (i % interval) != 0:
            pred_lw = cache_lib.layerwise_predict(pol, lw_state, t_now, h0)
            pred_crf = cache_lib.predict(pol, crf_state, t_now)
            denom = float(jnp.mean(jnp.square(crf)))
            mse_lw.append(float(jnp.mean(jnp.square(pred_lw - crf))) / denom)
            mse_crf.append(float(jnp.mean(jnp.square(pred_crf - crf)))
                           / denom)
        else:
            lw_state = cache_lib.layerwise_update(pol, lw_state, deltas,
                                                  t_now)
            crf_state = cache_lib.update(pol, crf_state, crf, t_now)
        v, _ = full_fn(x, t_now)
        x = x + (t_next - t_now) * v

    rows = [{
        "variant": "layer-wise (2L tensors)",
        "rel_mse_mean": round(float(np.mean(mse_lw)), 5),
        "rel_mse_p90": round(float(np.percentile(mse_lw, 90)), 5),
    }, {
        "variant": "CRF (1 tensor)",
        "rel_mse_mean": round(float(np.mean(mse_crf)), 5),
        "rel_mse_p90": round(float(np.percentile(mse_crf, 90)), 5),
    }, {
        "variant": "CRF/layer-wise ratio",
        "rel_mse_mean": round(float(np.mean(mse_crf) / np.mean(mse_lw)), 3),
        "rel_mse_p90": round(float(np.percentile(mse_crf, 90)
                                   / np.percentile(mse_lw, 90)), 3),
    }]
    B.print_table("Fig 4 — prediction MSE: layer-wise vs CRF caching", rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
