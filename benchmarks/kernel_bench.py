"""Kernel-path microbenchmarks -> ``results/bench/BENCH_kernels.json``.

Three rows, each pairing a measured wall time with a bytes-moved model
(the roofline-side story — on CPU the Pallas kernels run in interpret
mode, so the *bytes* columns are the load-bearing numbers and the
kernel wall times are correctness-priced, not speed-priced):

* ``cached_step`` — spatial low ring vs the spectral low ring at the
  paper's rho: state bytes, bytes the cached step must move, and the
  measured jnp cached-step wall for both layouts.  The CI guard asserts
  ``spectral_low_bytes <= rho * spatial_low_bytes + eps``.
* ``band_split`` — pure-jnp ``frequency.decompose`` (transform
  round-trip) vs the fused spectral Pallas kernel (one pass emitting
  ``(low_spec, high)``).
* ``attention`` — full-logits ``_sdpa`` vs the flash kernel at a shape
  above the DiT's ``_FLASH_MIN_SEQ`` routing threshold.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common as B
from repro.core import frequency
from repro.core.policies import base as policy_base
from repro.core.policies.freqca import FreqCaPolicy
from repro.kernels import dct as dct_kernel
from repro.models import attention as attn_lib

def _wall(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def _ring_bytes(ring: policy_base.Ring) -> int:
    return sum(x.size * x.dtype.itemsize for x in ring)


def cached_step_row(batch: int, s: int, d: int, rho: float) -> dict:
    """Spatial-vs-spectral cached step: state footprint + wall time."""
    pol = FreqCaPolicy(interval=5, method="dct", rho=rho)
    state = pol.init(batch, (s, d))
    ctx = policy_base.StepContext(
        step_idx=jnp.asarray(0), t_now=jnp.asarray(0.5),
        x=jnp.zeros((batch, 1)), batch=batch, feat_shape=(s, d))
    crf = jax.random.normal(jax.random.key(0), (batch, s, d))

    # a spatial twin of the same cache: low band stored at [B, K, S, D]
    spatial_low = policy_base.ring_init(batch, pol.k_low, (s, d))

    @jax.jit
    def spectral_step(st):
        st = pol.update(st, crf, ctx)
        return st, pol.predict(st, ctx)

    @jax.jit
    def spatial_step(low_ring, high_ring):
        bands = frequency.decompose(crf, rho, "dct")
        low_ring = policy_base.ring_push(low_ring, bands.low, ctx.t_now)
        high_ring = policy_base.ring_push(high_ring, bands.high, ctx.t_now)
        pred = (policy_base.ring_last(low_ring)
                + policy_base.ring_predict(high_ring, ctx.t_now,
                                           pol.high_order))
        return low_ring, high_ring, pred

    m = pol.spectral_bins(s)
    itemsize = 4
    spatial_low_bytes = batch * pol.k_low * s * d * itemsize
    spectral_low_bytes = _ring_bytes(state.low)
    high_bytes = batch * pol.k_high * s * d * itemsize
    return {
        "name": "cached_step",
        "batch": batch, "tokens": s, "d_model": d, "rho": rho,
        "kept_bins": m,
        "spatial_low_bytes": spatial_low_bytes,
        "spectral_low_bytes": spectral_low_bytes,
        "low_ring_compression": round(
            spatial_low_bytes / max(spectral_low_bytes, 1), 2),
        # cached-step HBM traffic model: read low ring + high ring,
        # write ẑ once
        "step_bytes_spatial": (spatial_low_bytes + high_bytes
                               + batch * s * d * itemsize),
        "step_bytes_spectral": (spectral_low_bytes + high_bytes
                                + batch * s * d * itemsize),
        "wall_spatial_ms": round(
            1e3 * _wall(spatial_step, spatial_low, state.high), 3),
        "wall_spectral_ms": round(1e3 * _wall(spectral_step, state), 3),
    }


def band_split_row(batch: int, s: int, d: int, rho: float) -> dict:
    """jnp transform round-trip vs fused spectral kernel (interpret)."""
    x = jax.random.normal(jax.random.key(1), (batch, s, d))
    itemsize = 4
    m = frequency.spectral_kept_bins(s, rho, "dct")

    jnp_split = jax.jit(lambda z: frequency.decompose(z, rho, "dct"))
    kern_split = jax.jit(lambda z: dct_kernel.band_split_spectral(
        z, rho, "dct", interpret=True))
    return {
        "name": "band_split",
        "batch": batch, "tokens": s, "d_model": d, "rho": rho,
        # jnp path: read x, write low + high (both spatial);
        # fused kernel: read x once, write low_spec + high
        "bytes_jnp": 3 * batch * s * d * itemsize,
        "bytes_kernel": (2 * batch * s * d + batch * m * d) * itemsize,
        "wall_jnp_ms": round(1e3 * _wall(jnp_split, x), 3),
        "wall_kernel_interpret_ms": round(1e3 * _wall(kern_split, x), 3),
    }


def attention_row(batch: int, s: int, heads: int, hd: int) -> dict:
    """Full-logits sdpa vs flash kernel (interpret), non-causal."""
    q = jax.random.normal(jax.random.key(2), (batch, s, heads, hd))
    k = jax.random.normal(jax.random.key(3), (batch, s, heads, hd))
    v = jax.random.normal(jax.random.key(4), (batch, s, heads, hd))
    mask = jnp.ones((1, s, s), bool)
    itemsize = 4
    sdpa = jax.jit(lambda a, b, c: attn_lib._sdpa(a, b, c, mask, 1))
    flash = jax.jit(_flash_call)
    return {
        "name": "attention",
        "batch": batch, "tokens": s, "heads": heads, "head_dim": hd,
        # sdpa materialises the [B, H, S, S] logits+probs at fusion
        # boundaries; flash keeps them in VMEM
        "bytes_sdpa": (3 * batch * s * heads * hd
                       + 2 * batch * heads * s * s
                       + batch * s * heads * hd) * itemsize,
        "bytes_flash": 4 * batch * s * heads * hd * itemsize,
        "wall_sdpa_ms": round(1e3 * _wall(sdpa, q, k, v), 3),
        "wall_flash_interpret_ms": round(1e3 * _wall(flash, q, k, v), 3),
    }


def _flash_call(q, k, v):
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, 1, causal=False, q_block=128,
                              kv_block=128, interpret=True)


def run(out: str = "results/bench/BENCH_kernels.json"):
    # call-time read: run.py --smoke sets BENCH_REDUCED after import
    if B.reduced():
        batch, s, d = 1, 256, 128
        attn_s, heads, hd = 256, 2, 32
    else:
        batch, s, d = 2, 1024, 512
        attn_s, heads, hd = 1024, 4, 64
    rho = 0.0625
    rows = [
        cached_step_row(batch, s, d, rho),
        band_split_row(batch, s, d, rho),
        attention_row(batch, attn_s, heads, hd),
    ]
    for row in rows:  # heterogeneous schemas: one table per row
        B.print_table(f"Kernel paths — {row['name']}", [row])
    step = rows[0]
    # the tentpole claim: the low ring shrank to ~rho of its spatial
    # footprint (one extra bin can survive rounding; eps covers the
    # [B, K] ts + head bookkeeping)
    eps = 1024 + step["spatial_low_bytes"] / s  # one spectral row
    assert (step["spectral_low_bytes"]
            <= rho * step["spatial_low_bytes"] + eps), step
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
