"""Paper Table 5: cache memory / latency comparison.

Measures actual cache-state bytes (pytree) per policy for the paper's
FLUX geometry (L=57 blocks, 4096 image tokens, d=3072) and for the bench
DiT, plus the paper's closed-form K_layer = 2(m+1)L vs K_FreqCa = 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as B
from repro.core import cache as cache_lib
from repro.core.cache import CachePolicy


def cache_units(policy: CachePolicy, n_layers: int) -> int:
    if policy.kind == "layerwise":
        return 2 * policy.k_high * n_layers
    return policy.cache_units


def run(out: str = "results/bench/table5.json"):
    # FLUX.1-dev geometry: L=57, 4096 img tokens (1024px/16/patch2), d=3072
    feat = (1, 4096, 3072)
    n_layers = 57
    rows = []
    for name, pol, layerwise in [
        ("layer-wise (ToCa/TaylorSeer-style)",
         CachePolicy(kind="taylorseer", high_order=2), True),
        ("TaylorSeer CRF", CachePolicy(kind="taylorseer", high_order=2),
         False),
        ("FORA CRF", CachePolicy(kind="fora"), False),
        ("FoCa CRF", CachePolicy(kind="foca", high_order=2), False),
        ("FreqCa (ours)", CachePolicy(kind="freqca", high_order=2), False),
    ]:
        if layerwise:
            state = cache_lib.layerwise_init(pol, 2 * n_layers, feat,
                                             dtype=jnp.bfloat16)
            nbytes = sum(x.size * x.dtype.itemsize
                         for x in jax.tree.leaves(state))
            units = 2 * pol.k_high * n_layers
        else:
            state = cache_lib.init_state(pol, feat, dtype=jnp.bfloat16)
            # policy-aware: the dummy low_hist slot kept for static
            # shapes must not inflate the Table-5 memory numbers
            nbytes = cache_lib.cache_bytes(state, pol)
            units = pol.cache_units
        rows.append({
            "method": name,
            "cache_units": units,
            "cache_gb": round(nbytes / 1e9, 4),
            "pct_of_layerwise": round(
                100 * units / (2 * 3 * n_layers), 2),
        })
    # beyond-paper row: the shipped policy object stores the low band
    # as kept_bins(S, rho) spectral rows, not S spatial rows — the
    # *real* serving footprint (what `DiffusionEngine.state_bytes` and
    # `ServeMetrics.cache_state_bytes_per_lane` report)
    from repro.core.policies.freqca import FreqCaPolicy
    spec_pol = FreqCaPolicy(interval=5, method="dct", high_order=2)
    spec_state = spec_pol.init(1, feat[1:], jnp.bfloat16)
    spec_bytes = spec_pol.state_bytes(spec_state)
    freqca_row = [r for r in rows if "FreqCa" in r["method"]][0]
    rows.append({
        "method": "FreqCa (ours, spectral low ring)",
        "cache_units": round(
            spec_pol.k_high
            + spec_pol.k_low * spec_pol.spectral_bins(feat[1]) / feat[1],
            3),
        "cache_gb": round(spec_bytes / 1e9, 4),
        "pct_of_layerwise": round(
            freqca_row["pct_of_layerwise"]
            * spec_bytes / max(freqca_row["cache_gb"] * 1e9, 1), 2),
    })
    # error-budgeted variant: identical rings + five per-lane feedback
    # scalars (two band rates, accumulator, peak, event count) — the
    # accounting must include them, and they must be noise next to the
    # spectral footprint
    from repro.core.policies.freqca_eb import FreqCaErrorBudgetPolicy
    eb_pol = FreqCaErrorBudgetPolicy(method="dct", high_order=2)
    eb_state = eb_pol.init(1, feat[1:], jnp.bfloat16)
    eb_bytes = eb_pol.state_bytes(eb_state)
    rows.append({
        "method": "FreqCa-EB (error-budgeted)",
        "cache_units": rows[-1]["cache_units"],
        "cache_gb": round(eb_bytes / 1e9, 4),
        "pct_of_layerwise": round(
            freqca_row["pct_of_layerwise"]
            * eb_bytes / max(freqca_row["cache_gb"] * 1e9, 1), 2),
    })
    B.print_table("Table 5 — cache memory (FLUX geometry, L=57, bf16)",
                  rows)
    # paper's claim: FreqCa ~1.17% of layer-wise; the spectral low ring
    # must come in strictly below the spatial FreqCa figure
    assert freqca_row["pct_of_layerwise"] < 2.0, freqca_row
    assert spec_bytes < freqca_row["cache_gb"] * 1e9, rows[-2]
    # the ErrorFeedback scalars are counted (strictly more bytes) but
    # stay within epsilon of the spectral FreqCa footprint
    assert spec_bytes < eb_bytes <= spec_bytes + 64, (spec_bytes, eb_bytes)
    assert rows[-1]["pct_of_layerwise"] < 2.0, rows[-1]
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
