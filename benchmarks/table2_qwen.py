"""Paper Table 2 (Qwen-Image grid) at CPU scale — FFT decomposition
(the paper's Qwen setting; appendix B.3)."""
from benchmarks import table1_flux


def main():
    table1_flux.run(method="fft",
                    title="Table 2 — Qwen-Image-like (FFT)",
                    out="results/bench/table2.json")


if __name__ == "__main__":
    main()
