"""Quality-SLO serving benchmark: error-budgeted activation vs the
scheduled interval, and load shedding under overload.  Emits
``results/bench/BENCH_serve_quality.json`` (asserted in CI).

Both parts run the trained bench DiT through a *stiff-dynamics*
wrapper: the DiT's time input is frozen (its own step-to-step CRF
drift at smoke step counts would swamp any budget tier) and the CRF is
modulated by a controlled oscillation whose amplitude decays along the
trajectory — ~0.5 rad of phase per sampler step at any ``n_steps``, so
the cache's per-step error rate is in the same meterable range at
smoke and full scale, and is *time-varying*, which is the regime
feedback-driven activation exists for.  The velocity is re-derived
from the modulated CRF, so cached steps approximate exactly the
trajectory full steps produce.

* **Pareto** — ``freqca_eb`` at each budget tier vs scheduled
  ``freqca`` at each interval.  Scheduled freqca is run through an
  instrumented variant (schedule-driven activation + the eb error
  meter) so both report the same *realized* cache error: the peak
  error accumulated between consecutive full forwards — the quantity
  ``max_error`` bounds.  Guarded: some eb point must skip MORE than a
  scheduled point at equal-or-lower realized error, and every eb
  point's realized error must respect its budget.  (Final-output
  ``rel_err`` vs the uncached baseline is recorded for context.)
* **Shed** — the same overload burst served twice through the engine:
  with shedding off, every request keeps its tight budget; with
  shedding on, requests submitted while the queue is >= ``shed_depth``
  deep have their budget relaxed by ``shed_factor`` (snapped to a
  looser tier) — quality is shed, requests never are.  Guarded:
  >= 1.1x req/s, zero drops, p95 realized error within the shed tier,
  zero steady-state recompiles (both tier ladders warmed).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks import common as B
from repro.core.policies import (FreqCaErrorBudgetPolicy, FreqCaPolicy,
                                 NoCachePolicy)
from repro.diffusion import sampler, schedule
from repro.serving import metrics as metrics_lib
from repro.serving.engine import DiffusionEngine, DiffusionRequest

BUDGETS = (0.05, 0.2, 0.5)
INTERVALS = (2, 3, 5)
AMP = 0.8


@dataclasses.dataclass(frozen=True)
class _SchedMeasured(FreqCaErrorBudgetPolicy):
    """Measurement instrument: interval-scheduled activation with the
    eb error meter still attached, so scheduled freqca reports the
    same realized-cache-error metric as the budgeted policy."""
    name = "freqca_sched_measured"

    def decide(self, state, ctx):
        warm = state.n_valid < self.needed_history + 1
        act = warm | ((ctx.step_idx % self.interval) == 0)
        rate = state.rate_low + state.rate_high
        acc = jnp.where(act, 0.0, state.acc + rate)
        return state._replace(acc=acc,
                              peak=jnp.maximum(state.peak, acc)), act


def _stiff_fns(cfg, params, n_steps):
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    freq = 0.5 * n_steps          # ~0.5 rad per step at any n_steps

    def stiff_full(x, t):
        _, crf = full_fn(x, jnp.full((), 0.5))
        # amplitude decays with t^2: early trajectory stiff, tail calm
        crf = crf * (1.0 + AMP * t * t * jnp.sin(freq * t))
        return from_crf_fn(crf, t), crf

    return stiff_full, from_crf_fn


def _pareto_rows(cfg, full_fn, from_crf_fn, n_steps):
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    x0 = jax.random.normal(jax.random.key(0),
                           (B.BATCH, B.IMG_SIZE, B.IMG_SIZE,
                            cfg.in_channels))
    ts = schedule.timesteps(n_steps)
    crf_shape = (B.BATCH, n_tok, cfg.d_model)

    def run_pol(pol):
        fn = jax.jit(lambda x: sampler.sample(
            full_fn, from_crf_fn, x, ts, pol, crf_shape=crf_shape))
        res = fn(x0)
        res.x.block_until_ready()
        return res

    def row(method, res):
        fulls = [int(v) for v in res.n_full_lanes]
        mean_full = sum(fulls) / len(fulls)
        return {
            "section": "pareto", "method": method,
            "n_full": round(mean_full, 2),
            "skips": round(n_steps - mean_full, 2),
            "realized": round(float(jnp.max(res.feedback.realized)), 4),
            "budget_events": int(jnp.sum(res.feedback.events)),
            "rel_err": round(float(
                jnp.linalg.norm(res.x - ref.x)
                / jnp.linalg.norm(ref.x)), 5),
        }

    ref = run_pol(NoCachePolicy())
    rows = []
    for interval in INTERVALS:
        res = run_pol(_SchedMeasured(interval=interval, method="dct",
                                     rho=0.25))
        rows.append(row(f"freqca(N={interval})", res))
    for budget in BUDGETS:
        pol = FreqCaErrorBudgetPolicy(method="dct",
                                      rho=0.25).with_budget(budget)
        res = run_pol(pol)
        r = row(f"freqca_eb(b={pol.budget})", res)
        # the budget is an SLO: realized cache error never exceeds it
        assert r["realized"] <= pol.budget + 1e-6, r
        rows.append(r)
    # the Pareto claim: feedback-placed fulls buy more skips per unit
    # of realized cache error than any fixed cadence
    sched = [r for r in rows if not r["method"].startswith("freqca_eb")]
    ebs = [r for r in rows if r["method"].startswith("freqca_eb")]
    wins = [(e["method"], s["method"]) for e in ebs for s in sched
            if e["realized"] <= s["realized"] + 1e-6
            and e["skips"] > s["skips"]]
    assert wins, rows
    for r in rows:
        r["pareto_wins"] = len(wins) if r is rows[-1] else None
    return rows, wins


def _shed_rows(cfg, full_fn, from_crf_fn, n_steps, n_requests, max_batch,
               tight, shed_factor, shed_depth):
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    tight_pol = FreqCaErrorBudgetPolicy(
        method="dct", rho=0.25).with_budget(tight)
    shed_pol = tight_pol.with_budget(tight * shed_factor)
    assert shed_pol.budget > tight_pol.budget
    rows = []
    for name, depth in [("no_shed", None), ("shed", shed_depth)]:
        eng = DiffusionEngine(
            full_fn, from_crf_fn,
            (B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels),
            (n_tok, cfg.d_model), tight_pol, n_steps=n_steps,
            max_batch=max_batch, shed_depth=depth,
            shed_factor=shed_factor)
        # both tier ladders warmed: overload serving stays compile-free
        eng.warmup(policies=[shed_pol] if depth is not None else ())
        warm_misses = eng.metrics.compile_misses
        for i in range(n_requests):
            eng.submit(DiffusionRequest(request_id=i, seed=i,
                                        max_error=tight))
        t0 = time.perf_counter()
        outs = eng.serve_until_drained()
        wall = time.perf_counter() - t0
        s = eng.metrics.summary()
        rows.append({
            "section": "shed", "engine": name,
            "submitted": n_requests, "served": len(outs),
            "dropped": n_requests - len(outs),
            "shed_events": s["shed_events"],
            "wall_s": round(wall, 3),
            "req_per_s": round(
                metrics_lib.throughput(eng.metrics, wall), 3),
            "full_step_fraction": s["full_step_fraction"],
            "realized_error_p95": s["realized_error_p95"],
            "budget_events": s["budget_events"],
            "tight_tier": tight_pol.budget,
            "shed_tier": shed_pol.budget,
            "steady_recompiles": s["compile_misses"] - warm_misses,
        })
    base, shed = rows
    shed["rps_vs_no_shed"] = round(
        shed["req_per_s"] / max(base["req_per_s"], 1e-9), 3)
    # shedding relaxes budgets, never drops: every request served, the
    # loosened tier still honored, and >= 1.1x the no-shed throughput
    for r in rows:
        assert r["dropped"] == 0, r
        assert r["steady_recompiles"] == 0, r
    assert base["shed_events"] == 0 and shed["shed_events"] > 0, rows
    assert shed["realized_error_p95"] <= shed_pol.budget + 1e-6, shed
    assert base["realized_error_p95"] <= tight_pol.budget + 1e-6, base
    assert shed["full_step_fraction"] < base["full_step_fraction"], rows
    assert shed["rps_vs_no_shed"] >= 1.1, rows
    return rows


def run(out: str = "results/bench/BENCH_serve_quality.json",
        n_steps: int = 0, n_requests: int = 16, max_batch: int = 4,
        tight: float = 0.05, shed_factor: float = 20.0,
        shed_depth: int = 4,
        title: str = "Quality SLO — error budgets, shedding"):
    n_steps = n_steps or max(B.N_STEPS, 32)
    cfg, params = B.get_model()
    full_fn, from_crf_fn = _stiff_fns(cfg, params, n_steps)
    pareto, wins = _pareto_rows(cfg, full_fn, from_crf_fn, n_steps)
    shed_rows = _shed_rows(cfg, full_fn, from_crf_fn, n_steps, n_requests,
                           max_batch, tight, shed_factor, shed_depth)
    B.print_table(title + " (Pareto)", pareto)
    B.print_table(title + " (shedding)", shed_rows)
    rows = pareto + shed_rows
    shed = rows[-1]
    print(f"eb pareto wins vs schedule: {wins}; shedding: "
          f"{shed['rps_vs_no_shed']}x req/s at p95 error "
          f"{shed['realized_error_p95']} <= tier {shed['shed_tier']}, "
          f"0 drops")
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
