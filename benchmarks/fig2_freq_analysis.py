"""Paper Figure 2: frequency-band dynamics of diffusion features.

(a)-(b) temporal cosine similarity of low/high bands across step
intervals; (c)-(d) trajectory continuity proxy: the relative magnitude
of the second temporal difference (low = smooth/continuous).  The paper's
claims to validate:
  * low band:  HIGH similarity, POOR continuity (jumps),
  * high band: LOWER similarity, GOOD continuity (predictable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as B
from repro.core import frequency
from repro.diffusion import sampler, schedule


def band_series(crfs: jnp.ndarray, rho: float, method: str):
    lows, highs = [], []
    for i in range(crfs.shape[0]):
        b = frequency.decompose(crfs[i], rho, method)
        lows.append(b.low)
        highs.append(b.high)
    return jnp.stack(lows), jnp.stack(highs)


def similarity_at_intervals(series: jnp.ndarray, intervals):
    out = {}
    t = series.shape[0]
    for k in intervals:
        sims = [float(frequency.cosine_similarity(series[i], series[i + k]))
                for i in range(0, t - k, max(1, (t - k) // 8))]
        out[k] = float(np.mean(sims))
    return out


def continuity(series: jnp.ndarray) -> float:
    """||second difference|| / ||first difference|| — lower = smoother
    (more continuous, easier to extrapolate)."""
    d1 = series[1:] - series[:-1]
    d2 = series[2:] - 2 * series[1:-1] + series[:-2]
    n1 = float(jnp.linalg.norm(d1.astype(jnp.float32)))
    n2 = float(jnp.linalg.norm(d2.astype(jnp.float32)))
    return n2 / max(n1, 1e-9)


def run(out: str = "results/bench/fig2.json"):
    cfg, params = B.get_model()
    full_fn, _ = B.make_fns(cfg, params)
    x0 = jax.random.normal(jax.random.key(3),
                           (2, B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels))
    ts = schedule.timesteps(B.N_STEPS)
    _, _, crfs = sampler.reference_features(full_fn, x0, ts)

    rows = []
    for method in ("dct", "fft"):
        for rho in (0.0625, 0.25):
            low, high = band_series(crfs, rho, method)
            intervals = [1, 2, 4, 8]
            sim_low = similarity_at_intervals(low, intervals)
            sim_high = similarity_at_intervals(high, intervals)
            c_low, c_high = continuity(low), continuity(high)
            for k in intervals:
                rows.append({"method": method, "rho": rho, "interval": k,
                             "cos_sim_low": round(sim_low[k], 4),
                             "cos_sim_high": round(sim_high[k], 4)})
            rows.append({"method": method, "rho": rho,
                         "interval": "2nd-diff ratio",
                         "cos_sim_low": round(c_low, 4),
                         "cos_sim_high": round(c_high, 4)})
            # paper-consistent claims that hold robustly at bench scale:
            # (i) the low band stays highly similar at EVERY interval
            #     (paper: "> 0.90 at most timesteps");
            assert min(sim_low.values()) > 0.9, (method, rho, sim_low)
            # (ii) high-band similarity decays FASTER with interval;
            decay_low = sim_low[1] - sim_low[8]
            decay_high = sim_high[1] - sim_high[8]
            assert decay_high > decay_low, (method, rho, sim_low, sim_high)
            # (iii) the high band is smoother along the trajectory
            #     (better extrapolable — lower 2nd/1st difference ratio).
            assert c_high < c_low, (method, rho, c_low, c_high)
    B.print_table("Fig 2 — band similarity & continuity "
                  "(low: similar but jumpy; high: continuous)", rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
