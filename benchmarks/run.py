"""Run every paper-table benchmark. One function per paper table/figure.

Prints markdown tables + a final ``name,us_per_call,derived`` CSV line
per benchmark (latency of the headline FreqCa config; derived = its
quality metric).

``--smoke`` shrinks the shared DiT (reduced dit-small, 16px latents,
few train/sample steps) and runs a representative subset so a CPU CI
job finishes in minutes; artifacts land in ``results/bench/BENCH_*``.
"""
from __future__ import annotations

import argparse
import os


def _enable_smoke() -> None:
    # must run before ``benchmarks.common`` is imported anywhere
    os.environ.setdefault("BENCH_REDUCED", "1")
    os.environ.setdefault("BENCH_IMG_SIZE", "16")
    os.environ.setdefault("BENCH_TRAIN_STEPS", "30")
    os.environ.setdefault("BENCH_SAMPLE_STEPS", "12")
    os.environ.setdefault("BENCH_BATCH", "2")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + few steps; CI-sized subset")
    args = ap.parse_args(argv)
    if args.smoke:
        _enable_smoke()

    from benchmarks import (fig2_freq_analysis, fig4_crf_mse, figc1_ablation,
                            kernel_bench, roofline, serve_chaos, serve_fleet,
                            serve_multires, serve_quality, serve_throughput,
                            table1_flux, table2_qwen, table3_kontext,
                            table4_qwen_edit, table5_memory)
    csv = ["name,us_per_call,derived"]

    def headline(rows, pick="freqca(N=5)", metric="psnr"):
        for r in rows:
            if r.get("method") == pick:
                lat = r.get("latency_s", 0.0) or 0.0
                return f"{lat * 1e6:.0f}", f"{metric}={r[metric]}"
        return "0", ""

    t1 = table1_flux.run()
    csv.append("table1_flux,%s,%s" % headline(t1))
    if not args.smoke:
        table2_qwen.main()
        t3 = table3_kontext.run()
        csv.append("table3_kontext,%s,%s" % headline(t3))
        table4_qwen_edit.main()
    t5 = table5_memory.run()
    csv.append("table5_memory,0,freqca_pct=%s"
               % t5[-1]["pct_of_layerwise"])
    kb = kernel_bench.run()
    csv.append("kernel_bench,0,low_ring_compression=%s"
               % kb[0]["low_ring_compression"])
    if not args.smoke:
        # fig2's low-band-similarity property only holds at the realistic
        # model scale, not the reduced smoke DiT
        f2 = fig2_freq_analysis.run()
        csv.append("fig2_freq_analysis,0,rows=%d" % len(f2))
    f4 = fig4_crf_mse.run()
    csv.append("fig4_crf_mse,0,crf_over_layerwise=%s"
               % f4[-1]["rel_mse_mean"])
    if not args.smoke:
        fc1 = figc1_ablation.run()
        csv.append("figc1_ablation,0,rows=%d" % len(fc1))
    sv = serve_throughput.run(
        n_requests=12 if args.smoke else 24,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_throughput,0,bucketed_speedup=%s"
               % sv[1]["speedup_vs_padmax"])
    svm = serve_throughput.run_mixed(
        n_requests=12 if args.smoke else 24,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_mixed,0,grouped_rps_ratio=%s"
               % svm[1]["rps_vs_ungrouped"])
    sva = serve_throughput.run_async(
        n_requests=14 if args.smoke else 26,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_async,0,rps_vs_single_thread=%s"
               % sva[-1]["rps_vs_single_thread"])
    svq = serve_quality.run(
        n_requests=12 if args.smoke else 24,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_quality,0,shed_rps_ratio=%s"
               % svq[-1]["rps_vs_no_shed"])
    svf = serve_fleet.run(
        n_requests=16 if args.smoke else 24,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_fleet,0,rps_vs_1replica=%s"
               % svf[-1]["rps_vs_1replica"])
    svc = serve_chaos.run(n_requests=8 if args.smoke else 12)
    csv.append("serve_chaos,0,restarts=%s" % svc[-1]["restarts"])
    svr = serve_multires.run(
        n_requests=18 if args.smoke else 24,
        max_batch=4 if args.smoke else 8)
    csv.append("serve_multires,0,rps_vs_singles=%s"
               % svr[1]["rps_vs_singles"])
    try:
        rl = roofline.run()
        csv.append("roofline,0,combos=%d" % len(rl))
    except Exception as e:  # dryrun results may not exist yet
        csv.append("roofline,0,skipped(%s)" % type(e).__name__)

    print("\n=== CSV ===")
    for line in csv:
        print(line)
    from benchmarks import common as B
    B.save_rows("results/bench/BENCH_summary.json",
                [{"line": line} for line in csv])


if __name__ == "__main__":
    main()
