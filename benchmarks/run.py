"""Run every paper-table benchmark. One function per paper table/figure.

Prints markdown tables + a final ``name,us_per_call,derived`` CSV line
per benchmark (latency of the headline FreqCa config; derived = its
quality metric).
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (fig2_freq_analysis, fig4_crf_mse, figc1_ablation,
                            roofline, table1_flux, table2_qwen,
                            table3_kontext, table4_qwen_edit, table5_memory)
    csv = ["name,us_per_call,derived"]

    def headline(rows, pick="freqca(N=5)", metric="psnr"):
        for r in rows:
            if r.get("method") == pick:
                lat = r.get("latency_s", 0.0) or 0.0
                return f"{lat * 1e6:.0f}", f"{metric}={r[metric]}"
        return "0", ""

    t1 = table1_flux.run()
    csv.append("table1_flux,%s,%s" % headline(t1))
    t2 = table2_qwen.main() or []
    t3 = table3_kontext.run()
    csv.append("table3_kontext,%s,%s" % headline(t3))
    table4_qwen_edit.main()
    t5 = table5_memory.run()
    csv.append("table5_memory,0,freqca_pct=%s"
               % t5[-1]["pct_of_layerwise"])
    f2 = fig2_freq_analysis.run()
    csv.append("fig2_freq_analysis,0,rows=%d" % len(f2))
    f4 = fig4_crf_mse.run()
    csv.append("fig4_crf_mse,0,crf_over_layerwise=%s"
               % f4[-1]["rel_mse_mean"])
    fc1 = figc1_ablation.run()
    csv.append("figc1_ablation,0,rows=%d" % len(fc1))
    try:
        rl = roofline.run()
        csv.append("roofline,0,combos=%d" % len(rl))
    except Exception as e:  # dryrun results may not exist yet
        csv.append("roofline,0,skipped(%s)" % type(e).__name__)

    print("\n=== CSV ===")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
