"""Availability under faults: kill 1 of 2 replicas mid-stream.

Two rows over the same request load on a 2-replica fleet:

* ``no_fault`` — the control: both replicas serve two waves cleanly
  (0 losses, 0 restarts, 0 steady-state recompiles);
* ``kill_one_of_two`` — a scripted ``FaultInjector`` SIGKILLs replica
  0 the moment its 2nd submit arrives (the pipe just EOFs, exactly
  like a real crash).  The router requeues the orphans onto the
  survivor, the supervisor restarts the slot, and a second wave runs
  after the rejoin.

The availability invariants (asserted here and guarded in CI from
``BENCH_serve_chaos.json``): every submitted future resolves exactly
once (served == submitted, 0 dropped, 0 unresolved), the fault row
records ``replicas_lost >= 1`` and ``restarts >= 1``, the restarted
replica serves post-rejoin work, fleet-wide in-flight never exceeded
``replicas x max_inflight``, and steady-state recompiles are 0 on
every replica — the restarted worker re-warms at boot, so a restart
costs downtime, never a compile in the serving path.

Run directly (``python -m benchmarks.serve_chaos``) or via
``benchmarks/run.py --smoke``; the ``__main__`` guard is mandatory —
the spawn start method re-imports this module in every worker.
"""
from __future__ import annotations

import functools
import time

from benchmarks import common as B
from repro.core.policies import FreqCaPolicy
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.fleet import FaultInjector, FleetRouter

MAX_BATCH = 4
MAX_INFLIGHT = 16
REJOIN_TIMEOUT_S = 300.0


def fleet_engine(max_batch: int, interval: int, max_wait_s: float):
    """Worker-side engine builder — module-level so its
    ``functools.partial`` pickles under spawn.  Each worker restores
    the checkpoint the parent's ``get_model()`` already trained."""
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    return DiffusionEngine(full_fn, from_crf_fn,
                           (B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels),
                           (n_tok, cfg.d_model),
                           FreqCaPolicy(interval=interval, method="dct"),
                           n_steps=B.N_STEPS, max_batch=max_batch,
                           max_wait_s=max_wait_s)


def _wave(router, start_rid: int, n: int):
    """Submit ``n`` requests and return their futures."""
    return [router.submit(DiffusionRequest(request_id=start_rid + i,
                                           seed=start_rid + i))
            for i in range(n)]


def _wait_rejoin(router, want: int, timeout_s: float) -> bool:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if router.status()["healthy_replicas"] >= want:
            return True
        time.sleep(0.25)
    return False


def run(out: str = "results/bench/BENCH_serve_chaos.json",
        n_requests: int = 12,
        title: str = "Chaos — kill 1 of 2 replicas mid-stream"):
    factory = functools.partial(fleet_engine, MAX_BATCH, 5, 0.02)
    B.get_model()               # train/restore once, before any spawn

    rows = []
    for scenario in ("no_fault", "kill_one_of_two"):
        faults = None
        if scenario == "kill_one_of_two":
            # replica 0's first incarnation dies on its 2nd submit;
            # later incarnations (the restart) run clean
            faults = FaultInjector(seed=0).kill_after_submits(
                2, slot=0, start_n=0)
        router = FleetRouter(factory, n_replicas=2,
                             max_inflight=MAX_INFLIGHT,
                             max_restarts=2,
                             restart_backoff_base_s=0.2,
                             fault_injector=faults)
        try:
            router.start()
            t0 = time.perf_counter()
            futs = _wave(router, 0, n_requests)
            router.drain()
            rejoined = _wait_rejoin(router, want=2,
                                    timeout_s=REJOIN_TIMEOUT_S)
            # post-rejoin wave: the restarted replica must take real
            # work again, with zero steady-state recompiles
            futs += _wave(router, n_requests, n_requests)
            router.drain()
            wall = time.perf_counter() - t0
            outs = [f.result(timeout=60.0) for f in futs]
            fm = router.fleet_metrics()
            status = router.status()
        finally:
            router.shutdown(drain=True)
        s = fm.summary()
        rt = s["routing"]
        steady = {idx: pr["steady_recompiles"]
                  for idx, pr in s["per_replica"].items()}
        submitted = 2 * n_requests
        sup = status.get("supervisor", {})
        rows.append({
            "scenario": scenario,
            "submitted": submitted,
            "served": len(outs),
            "dropped": submitted - len(outs),
            "unresolved": rt["submitted"] - rt["resolved"] - rt["failed"],
            "wall_s": round(wall, 3),
            "replicas_lost": rt["replicas_lost"],
            "restarts": sup.get("restarts", 0),
            "boot_failures": sup.get("boot_failures", 0),
            "replicas_retired": sup.get("replicas_retired", 0),
            "rejoined": rejoined,
            "requeued": rt["requeued"],
            "duplicate_results": rt["duplicate_results"],
            "poison_quarantined": rt["poison_quarantined"],
            "peak_inflight": rt["peak_inflight"],
            "inflight_bound": 2 * MAX_INFLIGHT,
            "steady_recompiles": steady,
            "restarted_replica_requests": (
                s["per_replica"].get(0, {}).get("requests", 0)
                if scenario == "kill_one_of_two" else None),
        })
    B.print_table(title, rows)

    # availability invariants — the CI guard re-checks these from the
    # emitted JSON, so keep the field names stable
    for r in rows:
        assert r["served"] == r["submitted"] and r["dropped"] == 0, r
        assert r["unresolved"] == 0, r
        assert r["poison_quarantined"] == 0, r
        assert r["peak_inflight"] <= r["inflight_bound"], r
        assert all(v == 0 for v in r["steady_recompiles"].values()), r
    control, chaos = rows
    assert control["replicas_lost"] == 0 and control["restarts"] == 0, rows
    assert chaos["replicas_lost"] >= 1, rows
    assert chaos["restarts"] >= 1 and chaos["rejoined"], rows
    assert chaos["requeued"] >= 1, rows
    # the restarted incarnation actually served post-rejoin traffic
    assert chaos["restarted_replica_requests"] > 0, rows
    B.save_rows(out, rows)
    return rows


if __name__ == "__main__":
    run()
