"""Serving throughput: continuous-batching bucketed engine vs the seed
pad-to-max engine on the same mixed-size request stream, plus an
open-loop Poisson client, a mixed-policy per-lane case, and the
threaded async submit path vs the single-thread open-loop replay
(``run_async`` -> ``BENCH_serve_async.json``, asserted in CI).

Closed loop: both engines run the identical FreqCa policy and trained
DiT; the only difference is batch formation — power-of-two bucket
signatures vs the seed's fixed pad-to-``max_batch`` signature.  Both
are warmed up first, so the timed phase measures steady-state serving
(the recompile counter must stay at zero).  The bucketed engine is then
re-run under an open-loop Poisson arrival process (rate scaled off its
closed-loop throughput) so the age-based batch former is exercised
under real queueing, not only drained bursts.  Emits
``results/bench/BENCH_serve.json``.

``run_mixed`` serves the same mixed-policy stream (freqca / fora /
freqca_a cycling) through two batch formers:

* **ungrouped** (the pre-grouping baseline): mixed-lane batches with
  per-lane activation — per-request ``n_full_steps`` must differ
  across policies, and every distinct lane-policy mix is its own jit
  signature;
* **grouped** (policy-homogeneous formation, the default engine mode):
  every cut is policy-pure, the compiled-signature count is capped at
  policy-groups x buckets (probed via ``compiled_buckets()`` and
  reported as ``compiled_signatures``), the skip-compute fraction
  rises (scheduled lanes stop paying for adaptive lanes' activations),
  and req/s must hold the ungrouped baseline on the identical stream.

Both serve with zero steady-state recompiles once warm.  Emits
``results/bench/BENCH_serve_mixed.json`` (asserted in CI).
"""
from __future__ import annotations

import time

from benchmarks import common as B
from repro.core.policies import (ForaPolicy, FreqCaAdaptivePolicy,
                                 FreqCaPolicy)
from repro.launch.serve import (mixed_stream, poisson_stream,
                                serve_open_loop, serve_stream,
                                serve_threaded_open_loop)
from repro.serving import metrics as metrics_lib
from repro.serving.engine import DiffusionEngine, DiffusionRequest


def _engine(full_fn, from_crf_fn, cfg, policy, max_batch, pad_to_max=False,
            max_wait_s=0.0, group_policies=False):
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    return DiffusionEngine(full_fn, from_crf_fn,
                           (B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels),
                           (n_tok, cfg.d_model), policy,
                           n_steps=B.N_STEPS, max_batch=max_batch,
                           pad_to_max=pad_to_max, max_wait_s=max_wait_s,
                           group_policies=group_policies)


def run(out: str = "results/bench/BENCH_serve.json",
        n_requests: int = 24, max_batch: int = 8, interval: int = 5,
        title: str = "Serving throughput — bucketed vs pad-to-max"):
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    policy = FreqCaPolicy(interval=interval, method="dct")

    def row(name, eng, outs, wall, warm, warm_misses):
        assert len(outs) == n_requests
        s = eng.metrics.summary()
        return {
            "engine": name,
            "requests": n_requests,
            "wall_s": round(wall, 3),
            "req_per_s": round(metrics_lib.throughput(eng.metrics, wall), 3),
            "mean_occupancy": s["mean_occupancy"],
            "mean_bucket": s["mean_bucket"],
            "latency_p50_s": s["request_latency_p50_s"],
            "latency_p95_s": s["request_latency_p95_s"],
            "full_step_fraction": s["full_step_fraction"],
            "request_full_p50": s["request_full_p50"],
            "warmup_s": round(warm, 2),
            "warmup_compiles": warm_misses,
            "steady_recompiles": s["compile_misses"] - warm_misses,
            "cache_state_bytes_per_lane": s["cache_state_bytes_per_lane"],
        }

    rows = []
    for name, pad in [("pad_to_max (seed)", True), ("bucketed", False)]:
        eng = _engine(full_fn, from_crf_fn, cfg, policy, max_batch,
                      pad_to_max=pad)
        # pad-to-max only ever sees one signature; bucketed precompiles
        # the whole ladder — both amortised over the process lifetime
        warm = eng.warmup(buckets=[max_batch] if pad else None)
        warm_misses = eng.metrics_dict()["compile_misses"]
        bursts = mixed_stream(n_requests, B.IMG_SIZE, cfg.in_channels,
                              edit_every=4)
        outs, wall = serve_stream(eng, bursts)
        rows.append(row(name, eng, outs, wall, warm, warm_misses))

    # open-loop Poisson client against the bucketed engine: arrivals at
    # ~75% of its closed-loop throughput, batches cut by queue pressure
    rate = max(0.75 * rows[-1]["req_per_s"], 0.5)
    eng = _engine(full_fn, from_crf_fn, cfg, policy, max_batch,
                  max_wait_s=0.02)
    warm = eng.warmup()
    warm_misses = eng.metrics_dict()["compile_misses"]
    plan = poisson_stream(n_requests, rate, B.IMG_SIZE, cfg.in_channels,
                          edit_every=4)
    outs, wall = serve_open_loop(eng, plan)
    rows.append(row(f"bucketed+poisson({rate:.2f}/s)", eng, outs, wall,
                    warm, warm_misses))

    base = rows[0]
    for r in rows:
        r["speedup_vs_padmax"] = round(
            r["req_per_s"] / max(base["req_per_s"], 1e-9), 2)
    B.print_table(title, rows)
    bucketed = rows[1]
    print(f"bucketed vs pad-to-max: {bucketed['speedup_vs_padmax']}x "
          f"req/s, steady-state recompiles: "
          f"{bucketed['steady_recompiles']}")
    B.save_rows(out, rows)
    return rows


def run_mixed(out: str = "results/bench/BENCH_serve_mixed.json",
              n_requests: int = 12, max_batch: int = 4, interval: int = 5,
              title: str = "Mixed-policy serving — grouped vs ungrouped"):
    from repro.core.policies import registry as policy_registry
    from repro.launch.serve import _make_request
    from repro.serving.scheduler import bucket_sizes

    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    default = FreqCaPolicy(interval=interval, method="dct")
    policies = [default,
                ForaPolicy(interval=max(interval // 2, 1)),
                FreqCaAdaptivePolicy(method="dct", rho=0.25,
                                     tea_threshold=0.3)]
    n_groups = len({policy_registry.compatibility_key(p)
                    for p in policies})
    budget = n_groups * len(bucket_sizes(max_batch))

    def stream():
        # one burst, policies cycling: the ungrouped former cuts mixed
        # FIFO windows; the grouped former cuts one pure batch per
        # policy from the same queue — identical requests either way
        return [[_make_request(rid, B.IMG_SIZE, cfg.in_channels,
                               edit_every=4, policies=policies)
                 for rid in range(n_requests)]]

    rows = []
    for name, grouped in [("ungrouped (per-mix sigs)", False),
                          ("grouped (policy-pure)", True)]:
        eng = _engine(full_fn, from_crf_fn, cfg, default, max_batch,
                      group_policies=grouped)
        # grouped: one uniform ladder per compatibility group covers
        # every signature a policy-pure former can cut.  Ungrouped: the
        # first serving pass mints each mixed-lane signature; the timed
        # second pass must be all hits either way.
        eng.warmup(policies=policies if grouped else ())
        serve_stream(eng, stream())
        warm_misses = eng.metrics_dict()["compile_misses"]
        outs, wall = serve_stream(eng, stream())
        s = eng.metrics.summary()
        fulls = {}
        for pol in policies:
            f = [o.n_full_steps for o in outs
                 if policies[o.request_id % len(policies)] == pol]
            fulls[pol.name] = round(sum(f) / max(len(f), 1), 2)
        rows.append({
            "engine": name,
            "grouped": grouped,
            "requests": len(outs),
            "wall_s": round(wall, 3),
            "req_per_s": round(len(outs) / max(wall, 1e-9), 3),
            "steady_recompiles": s["compile_misses"] - warm_misses,
            "compiled_signatures": s["compiled_signatures"],
            "signature_budget": budget,
            "policy_groups": s["policy_groups"],
            "skip_compute_fraction": s["skip_compute_fraction"],
            "max_lane_full_spread": s["max_lane_full_spread"],
            "mean_full_steps": fulls,
            "n_steps": B.N_STEPS,
        })

    ung, grp = rows
    grp["rps_vs_ungrouped"] = round(
        grp["req_per_s"] / max(ung["req_per_s"], 1e-9), 3)
    B.print_table(title, rows)
    # ungrouped: per-lane activation must actually decouple the lanes
    assert ung["max_lane_full_spread"] > 0, ung
    assert ung["mean_full_steps"]["fora"] != \
        ung["mean_full_steps"]["freqca_a"], ung
    # both formers serve compile-free once warm
    assert all(r["steady_recompiles"] == 0 for r in rows), rows
    # grouping caps the signature count at groups x buckets and raises
    # the skip-compute fraction (no cross-policy activation coupling) …
    assert grp["compiled_signatures"] <= budget, grp
    assert grp["policy_groups"] == n_groups, grp
    assert grp["skip_compute_fraction"] > ung["skip_compute_fraction"], rows
    # … while holding the ungrouped baseline's throughput on the same
    # stream (0.97: same tolerance as the async CI guard)
    assert grp["rps_vs_ungrouped"] >= 0.97, rows
    B.save_rows(out, rows)
    return rows


def run_async(out: str = "results/bench/BENCH_serve_async.json",
              n_requests: int = 14, max_batch: int = 4, interval: int = 5,
              clients: int = 4,
              title: str = "Async serving — threaded clients vs "
                           "single-thread open loop"):
    """Same Poisson arrival plan, same engine config, two clients:

    * single-thread open-loop replay (the PR-2 baseline): one thread
      interleaves submits with engine turns, so a busy engine delays
      every later arrival's submission;
    * N client threads through ``AsyncDiffusionEngine``: ``submit``
      returns a future immediately and the worker overlaps the clients.

    The arrival rate is set above the engine's drained capacity so the
    run is server-bound — the async path must reach at least the
    single-thread req/s with zero steady-state recompiles and every
    submitted future resolved.  (Throughput on one device is
    work-conserving either way; the async edge is structural — clients
    signal completion, so the tail batch is drained instead of aging
    out ``max_wait_s``, on top of the p95/TTFR latency win.)
    """
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    policy = FreqCaPolicy(interval=interval, method="dct")

    # n_requests deliberately NOT a multiple of max_batch: under
    # overload the stream ends in a partial batch, which the sync
    # replay must age out (max_wait_s) while the async client drains it
    if n_requests % max_batch == 0:
        n_requests += 1

    def fresh_engine():
        eng = _engine(full_fn, from_crf_fn, cfg, policy, max_batch,
                      max_wait_s=0.15)
        eng.warmup()
        return eng, eng.metrics_dict()["compile_misses"]

    # capacity probe on a warmed engine: drain one full bucket, so the
    # arrival rate can be set above what the server can absorb
    probe, _ = fresh_engine()
    t0 = time.perf_counter()
    for i in range(max_batch):
        probe.submit(DiffusionRequest(request_id=i, seed=i))
    probe.serve_until_drained()
    capacity = max_batch / max(time.perf_counter() - t0, 1e-9)
    rate = 1.5 * capacity

    rows = []
    for name, threaded in [("open_loop_1thread", False),
                           (f"async_threaded(clients={clients})", True)]:
        eng, warm_misses = fresh_engine()
        # identical arrival plan (same seed), fresh request objects
        plan = poisson_stream(n_requests, rate, B.IMG_SIZE,
                              cfg.in_channels, edit_every=4)
        if threaded:
            outs, wall = serve_threaded_open_loop(eng, plan,
                                                  clients=clients)
        else:
            outs, wall = serve_open_loop(eng, plan)
        s = eng.metrics.summary()
        rows.append({
            "engine": name,
            "clients": clients if threaded else 1,
            "submitted": n_requests,
            "served": len(outs),
            "arrival_rate": round(rate, 3),
            "wall_s": round(wall, 3),
            "req_per_s": round(metrics_lib.throughput(eng.metrics, wall), 3),
            "latency_p50_s": s["request_latency_p50_s"],
            "latency_p95_s": s["request_latency_p95_s"],
            "time_to_first_result_s": s["time_to_first_result_s"],
            "max_queue_depth": s["max_queue_depth"],
            "steady_recompiles": s["compile_misses"] - warm_misses,
        })

    single, threaded_row = rows
    ratio = round(threaded_row["req_per_s"]
                  / max(single["req_per_s"], 1e-9), 3)
    threaded_row["rps_vs_single_thread"] = ratio
    B.print_table(title, rows)
    # every submitted future resolved; nothing lost or double-served
    for r in rows:
        assert r["served"] == r["submitted"], r
        assert r["steady_recompiles"] == 0, r
    # the threaded async client must keep up with the sync replay
    assert ratio >= 0.97, rows
    B.save_rows(out, rows)
    return rows


def main():
    run()
    run_mixed()
    run_async()


if __name__ == "__main__":
    main()
