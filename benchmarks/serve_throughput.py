"""Serving throughput: continuous-batching bucketed engine vs the seed
pad-to-max engine on the same mixed-size request stream.

Both engines run the identical FreqCa policy and trained DiT; the only
difference is batch formation — power-of-two bucket signatures vs the
seed's fixed pad-to-``max_batch`` signature.  Both are warmed up first,
so the timed phase measures steady-state serving (the recompile counter
must stay at zero).  Emits ``results/bench/BENCH_serve.json``.
"""
from __future__ import annotations

from benchmarks import common as B
from repro.core.cache import CachePolicy
from repro.launch.serve import mixed_stream, serve_stream
from repro.serving import metrics as metrics_lib
from repro.serving.engine import DiffusionEngine


def run(out: str = "results/bench/BENCH_serve.json",
        n_requests: int = 24, max_batch: int = 8, interval: int = 5,
        title: str = "Serving throughput — bucketed vs pad-to-max"):
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    policy = CachePolicy(kind="freqca", interval=interval, method="dct")

    def engine(pad_to_max: bool) -> DiffusionEngine:
        return DiffusionEngine(full_fn, from_crf_fn,
                               (B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels),
                               (n_tok, cfg.d_model), policy,
                               n_steps=B.N_STEPS, max_batch=max_batch,
                               pad_to_max=pad_to_max)

    rows = []
    for name, pad in [("pad_to_max (seed)", True), ("bucketed", False)]:
        eng = engine(pad)
        # pad-to-max only ever sees one signature; bucketed precompiles
        # the whole ladder — both amortised over the process lifetime
        warm = eng.warmup(buckets=[max_batch] if pad else None)
        warm_misses = eng.metrics.compile_misses
        bursts = mixed_stream(n_requests, B.IMG_SIZE, cfg.in_channels,
                              edit_every=4)
        outs, wall = serve_stream(eng, bursts)
        assert len(outs) == n_requests
        s = eng.metrics.summary()
        steady_recompiles = s["compile_misses"] - warm_misses
        rows.append({
            "engine": name,
            "requests": n_requests,
            "wall_s": round(wall, 3),
            "req_per_s": round(metrics_lib.throughput(eng.metrics, wall), 3),
            "mean_occupancy": s["mean_occupancy"],
            "mean_bucket": s["mean_bucket"],
            "latency_p50_s": s["request_latency_p50_s"],
            "latency_p95_s": s["request_latency_p95_s"],
            "full_step_fraction": s["full_step_fraction"],
            "warmup_s": round(warm, 2),
            "warmup_compiles": warm_misses,
            "steady_recompiles": steady_recompiles,
        })

    base, bucketed = rows[0], rows[1]
    for r in rows:
        r["speedup_vs_padmax"] = round(
            r["req_per_s"] / max(base["req_per_s"], 1e-9), 2)
    B.print_table(title, rows)
    print(f"bucketed vs pad-to-max: {bucketed['speedup_vs_padmax']}x "
          f"req/s, steady-state recompiles: "
          f"{bucketed['steady_recompiles']}")
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
