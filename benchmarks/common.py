"""Shared benchmark plumbing: one trained dit-small reused by every
paper-table benchmark, image metrics (PSNR/SSIM), policy sweep runner."""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as config_lib
from repro.checkpointing import checkpoint
from repro.core.cache import CachePolicy
from repro.diffusion import sampler, schedule
from repro.launch.train import train_dit
from repro.models import common as mcommon
from repro.models import dit

# --smoke (benchmarks/run.py) shrinks everything via these env knobs.
# Read at *call* time, never at import: the fleet router (and run.py
# itself) set the knobs after this module may already be imported, and
# an import-frozen read would silently pin full-scale settings — the
# same bug class as the PR-4 INTERPRET freeze (see repro.analysis's
# env-read-at-import rule).  The legacy module-level names (B.IMG_SIZE
# etc.) still work via the PEP 562 __getattr__ below, which re-reads
# the environment on every attribute access.


def reduced() -> bool:
    return os.environ.get("BENCH_REDUCED", "") == "1"


def ckpt_dir() -> str:
    return "results/bench_ckpt_smoke" if reduced() else "results/bench_ckpt"


def img_size() -> int:
    return int(os.environ.get("BENCH_IMG_SIZE", "32"))


def train_steps() -> int:
    return int(os.environ.get("BENCH_TRAIN_STEPS", "200"))


def sample_steps() -> int:
    return int(os.environ.get("BENCH_SAMPLE_STEPS", "50"))


def bench_batch() -> int:
    return int(os.environ.get("BENCH_BATCH", "4"))


_ENV_ATTRS = {
    "REDUCED": reduced, "CKPT_DIR": ckpt_dir, "IMG_SIZE": img_size,
    "TRAIN_STEPS": train_steps, "N_STEPS": sample_steps,
    "BATCH": bench_batch,
}


def __getattr__(name: str):
    fn = _ENV_ATTRS.get(name)
    if fn is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return fn()


def get_model():
    """Train (once) and cache the small DiT used by the quality benches."""
    cfg = config_lib.get_config("dit-small")
    if reduced():
        cfg = config_lib.reduced(cfg)
    specs = dit.dit_specs(cfg)
    like = mcommon.init_params(specs, jax.random.key(0),
                               jnp.dtype(cfg.dtype))
    ckpt = ckpt_dir()
    step = checkpoint.latest_step(ckpt, "dit")
    if step >= 0:
        params = checkpoint.restore(ckpt, step, like, name="dit")
    else:
        params = train_dit(cfg, train_steps(), 16, ckpt_dir=ckpt,
                           size=img_size())
    return cfg, params


def make_fns(cfg, params):
    size = img_size()

    def full_fn(x, t):
        tb = jnp.full((x.shape[0],), t)
        out = dit.dit_forward(params, x, tb, cfg)
        return out.velocity, out.crf

    def from_crf_fn(crf, t):
        tb = jnp.full((crf.shape[0],), t)
        return dit.dit_from_crf(params, crf, tb, cfg, size, size)

    return full_fn, from_crf_fn


def denoiser_flops_per_step(cfg) -> float:
    """Analytic FLOPs of one denoiser forward (batch 1)."""
    s = (img_size() // cfg.patch_size) ** 2
    per_layer = (4 * cfg.d_model ** 2 + 2 * cfg.d_model * cfg.d_ff
                 ) * 2 * s + 2 * 2 * s * s * cfg.d_model
    return (cfg.n_layers + 2 * cfg.n_double) * per_layer


def psnr(a, b, data_range: float = 2.0) -> float:
    mse = float(jnp.mean(jnp.square(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
    if mse == 0:
        return float("inf")
    return float(10.0 * np.log10(data_range ** 2 / mse))


def ssim(a, b, data_range: float = 2.0) -> float:
    """Global-statistics SSIM per channel (adequate at 32x32 bench scale)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    c1, c2 = (0.01 * data_range) ** 2, (0.03 * data_range) ** 2
    mu_a, mu_b = a.mean(), b.mean()
    va, vb = a.var(), b.var()
    cov = ((a - mu_a) * (b - mu_b)).mean()
    return float(((2 * mu_a * mu_b + c1) * (2 * cov + c2))
                 / ((mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2)))


def run_policy(cfg, full_fn, from_crf_fn, policy: CachePolicy,
               x0: jnp.ndarray, n_steps: Optional[int] = None,
               time_it: bool = True) -> Dict:
    if n_steps is None:
        n_steps = sample_steps()
    ts = schedule.timesteps(n_steps)
    n_tok = (img_size() // cfg.patch_size) ** 2
    crf_shape = (x0.shape[0], n_tok, cfg.d_model)

    fn = jax.jit(lambda x: sampler.sample(full_fn, from_crf_fn, x, ts,
                                          policy, crf_shape=crf_shape))
    res = fn(x0)
    res.x.block_until_ready()
    wall = None
    if time_it:
        t0 = time.perf_counter()
        res = fn(x0)
        res.x.block_until_ready()
        wall = time.perf_counter() - t0
    n_full = int(res.n_full)
    flops = n_full * denoiser_flops_per_step(cfg) * x0.shape[0]
    return {"x": res.x, "n_full": n_full, "wall_s": wall,
            "flops": flops,
            "flops_speedup": n_steps / max(n_full, 1)}


def quality_row(name: str, res: Dict, ref_x, base_wall: float,
                base_flops: float) -> Dict:
    wall = res["wall_s"] or 0.0
    return {
        "method": name,
        "latency_s": round(wall, 3),
        "speed": round(base_wall / wall, 2) if wall else 0.0,
        "flops_speedup": round(base_flops / max(res["flops"], 1), 2),
        "n_full": res["n_full"],
        "psnr": round(psnr(res["x"], ref_x), 2),
        "ssim": round(ssim(res["x"], ref_x), 3),
        "rel_err": round(float(
            jnp.linalg.norm((res["x"] - ref_x).astype(jnp.float32))
            / jnp.linalg.norm(ref_x.astype(jnp.float32))), 4),
    }


def print_table(title: str, rows: List[Dict]):
    if not rows:
        return
    cols = list(rows[0].keys())
    print(f"\n### {title}")
    print(" | ".join(cols))
    print(" | ".join(["---"] * len(cols)))
    for r in rows:
        print(" | ".join(str(r[c]) for c in cols))


def save_rows(path: str, rows: List[Dict]):
    import json
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
