"""Fleet serving: 1 vs 2 engine replicas on the identical Poisson
arrival stream.

Both rows boot a ``FleetRouter`` over N worker processes (each worker
restores the shared bench checkpoint, builds its own engine, and warms
its bucket ladder), then replay the *same* timestamped arrival plan
(same seed, same rate) through threaded clients.  The arrival rate is
set well above one engine's drained capacity, so the single-replica
row is server-bound and the two-replica row measures real horizontal
scaling: on a host with cores to spare the 2-replica row must reach
>= 1.5x the 1-replica req/s (asserted in CI), with zero dropped or
unresolved futures and zero steady-state recompiles on every replica
— warmup per process, never per request.

On a host without enough cores to run two jax processes concurrently
(``os.cpu_count() < 3``: two busy workers + the router would timeshare
one core) the scaling assertion is recorded but not enforced —
``host_limited`` marks the row so CI guards key off the flag instead
of silently passing.  Emits ``results/bench/BENCH_serve_fleet.json``.

Run directly (``python -m benchmarks.serve_fleet``) or via
``benchmarks/run.py --smoke``; the ``__main__`` guard is mandatory —
the spawn start method re-imports this module in every worker.
"""
from __future__ import annotations

import functools
import os
import time

from benchmarks import common as B
from repro.core.policies import FreqCaPolicy
from repro.launch.serve import poisson_stream, serve_fleet_open_loop
from repro.serving.engine import DiffusionEngine, DiffusionRequest
from repro.serving.fleet import FleetRouter


def fleet_engine(max_batch: int, interval: int, max_wait_s: float):
    """Worker-side engine builder — module-level so its
    ``functools.partial`` pickles under spawn.  Each worker restores
    the checkpoint the parent's ``get_model()`` already trained."""
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    n_tok = (B.IMG_SIZE // cfg.patch_size) ** 2
    return DiffusionEngine(full_fn, from_crf_fn,
                           (B.IMG_SIZE, B.IMG_SIZE, cfg.in_channels),
                           (n_tok, cfg.d_model),
                           FreqCaPolicy(interval=interval, method="dct"),
                           n_steps=B.N_STEPS, max_batch=max_batch,
                           max_wait_s=max_wait_s)


def run(out: str = "results/bench/BENCH_serve_fleet.json",
        n_requests: int = 16, max_batch: int = 4, interval: int = 5,
        clients: int = 4,
        title: str = "Fleet serving — 1 vs 2 replicas, same stream"):
    factory = functools.partial(fleet_engine, max_batch, interval, 0.02)

    # capacity probe in-process: drain one full bucket on a warmed
    # engine, then set the arrival rate far enough above capacity that
    # one replica is saturated and two have headroom to show scaling
    probe = factory()
    probe.warmup(buckets=[max_batch])
    t0 = time.perf_counter()
    for i in range(max_batch):
        probe.submit(DiffusionRequest(request_id=i, seed=i))
    probe.serve_until_drained()
    capacity = max_batch / max(time.perf_counter() - t0, 1e-9)
    rate = 3.0 * capacity
    del probe

    host_cpus = os.cpu_count() or 1
    host_limited = host_cpus < 3
    rows = []
    for n_replicas in (1, 2):
        router = FleetRouter(factory, n_replicas=n_replicas)
        try:
            router.start()
            # identical arrival plan both rows: same seed, same rate
            plan = poisson_stream(n_requests, rate, B.IMG_SIZE,
                                  B.get_model()[0].in_channels,
                                  edit_every=0)
            outs, wall = serve_fleet_open_loop(router, plan,
                                               clients=clients)
            fm = router.fleet_metrics()
        finally:
            router.shutdown(drain=True)
        s = fm.summary()
        fleet, rt = s["fleet"], s["routing"]
        steady = {idx: pr["steady_recompiles"]
                  for idx, pr in s["per_replica"].items()}
        rows.append({
            "replicas": n_replicas,
            "submitted": n_requests,
            "served": len(outs),
            "dropped": n_requests - len(outs),
            "unresolved": rt["submitted"] - rt["resolved"] - rt["failed"],
            "arrival_rate": round(rate, 3),
            "wall_s": round(wall, 3),
            "req_per_s": round(len(outs) / max(wall, 1e-9), 3),
            "latency_p50_s": fleet["request_latency_p50_s"],
            "latency_p95_s": fleet["request_latency_p95_s"],
            "mean_occupancy": fleet["mean_occupancy"],
            "steady_recompiles": steady,
            "affinity_hits": rt["affinity_hits"],
            "spills": rt["spills"],
            "requeued": rt["requeued"],
            "replicas_lost": rt["replicas_lost"],
            "host_cpus": host_cpus,
            "host_limited": host_limited,
        })

    one, two = rows
    two["rps_vs_1replica"] = round(
        two["req_per_s"] / max(one["req_per_s"], 1e-9), 3)
    B.print_table(title, rows)

    # hard invariants on every host: nothing dropped, nothing left
    # unresolved, no replica ever recompiles once warm, no losses
    for r in rows:
        assert r["served"] == r["submitted"] and r["dropped"] == 0, r
        assert r["unresolved"] == 0, r
        assert all(v == 0 for v in r["steady_recompiles"].values()), r
        assert r["replicas_lost"] == 0 and r["requeued"] == 0, r
    # the scaling claim needs cores: router + 2 busy workers.  CI
    # runners have them; a 1-core dev box records host_limited instead
    if not host_limited:
        assert two["rps_vs_1replica"] >= 1.5, rows
    else:
        print(f"host_limited: {host_cpus} cpus — 2-replica scaling "
              f"({two['rps_vs_1replica']}x) recorded, not asserted")
    B.save_rows(out, rows)
    return rows


if __name__ == "__main__":
    run()
