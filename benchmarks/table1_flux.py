"""Paper Table 1 (FLUX.1-dev grid) at CPU scale.

DCT decomposition (the paper's FLUX setting).  Compares FreqCa against
FORA (reuse), TaylorSeer (forecast) and plain step reduction at matched
intervals; ImageReward/CLIP are replaced by PSNR/SSIM/relative error vs
the 50-step uncached model (the paper's own perceptual columns are this
comparison).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as B
from repro.core.cache import CachePolicy
from repro.diffusion import sampler, schedule


def run(method: str = "dct", title: str = "Table 1 — FLUX.1-dev-like (DCT)",
        out: str = "results/bench/table1.json"):
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    x0 = jax.random.normal(jax.random.key(42),
                           (B.BATCH, B.IMG_SIZE, B.IMG_SIZE,
                            cfg.in_channels))

    base = B.run_policy(cfg, full_fn, from_crf_fn, CachePolicy(kind="none"),
                        x0)
    rows = [B.quality_row(f"{B.N_STEPS} steps (baseline)", base, base["x"],
                          base["wall_s"], base["flops"])]

    # step-reduction baselines (fewer solver steps, no caching)
    for frac, nm in [(0.5, "50% steps"), (0.2, "20% steps")]:
        n = max(int(B.N_STEPS * frac), 2)
        red = B.run_policy(cfg, full_fn, from_crf_fn,
                           CachePolicy(kind="none"), x0, n_steps=n)
        rows.append(B.quality_row(nm, red, base["x"], base["wall_s"],
                                  base["flops"]))

    for interval in (3, 5, 7, 10):
        for kind in ("fora", "taylorseer", "foca", "freqca"):
            pol = CachePolicy(kind=kind, interval=interval, method=method,
                              rho=0.0625, high_order=2)
            res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0)
            rows.append(B.quality_row(f"{kind}(N={interval})", res,
                                      base["x"], base["wall_s"],
                                      base["flops"]))

    # TeaCache-style adaptive-threshold reuse baseline (paper Table 1)
    for thresh in (0.1, 0.25, 0.5):
        pol = CachePolicy(kind="teacache", tea_threshold=thresh)
        res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0)
        rows.append(B.quality_row(f"teacache(l={thresh})", res,
                                  base["x"], base["wall_s"],
                                  base["flops"]))

    # beyond-paper: FreqCa-A — FreqCa predictor + self-calibrated adaptive
    # schedule (error budget from the free activated-step prediction error)
    for tol in (0.2, 0.4, 0.8):
        pol = CachePolicy(kind="freqca_a", tea_threshold=tol,
                          method=method, rho=0.25, high_order=2)
        res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0)
        rows.append(B.quality_row(f"freqca_a(tol={tol})", res,
                                  base["x"], base["wall_s"],
                                  base["flops"]))

    B.print_table(title, rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
