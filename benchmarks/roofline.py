"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run JSONs (results/dryrun/*.json; produce them with
``python -m repro.launch.dryrun --all [--multi-pod]``).

Per combo: compute/memory/collective terms in seconds (v5e constants),
the dominant bottleneck, MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference), and the MODEL/HLO flops ratio (compiled-compute
usefulness — catches remat & dispatch waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

import numpy as np

import repro.configs as config_lib
from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.roofline import analysis
from benchmarks import common as B


def _numel(spec_tree) -> int:
    import jax
    leaves = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) for s in leaves)


def active_params(cfg: ModelConfig) -> float:
    """Parameter count with only top_k of n_experts active."""
    from repro.launch import steps as steps_lib
    specs = steps_lib.model_specs(cfg)
    total = _numel(specs)
    if cfg.moe is None or cfg.moe.n_experts == 0:
        return float(total)
    import jax
    expert_numel = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        if any(k in ("wi_gate", "wi_up", "wo") for k in keys) and \
                leaf.axes[0] == "layer" and "expert" in leaf.axes:
            expert_numel += int(np.prod(leaf.shape))
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return float(total - expert_numel * (1.0 - frac))


def model_flops_for(arch: str, shape: str) -> float:
    if shape in ("denoise_step", "cached_step"):
        from repro.models import dit as dit_mod
        cfg = config_lib.get_config(arch)
        if shape == "cached_step":
            # cached step has no model matmuls beyond the final layer
            pdim = cfg.patch_size ** 2 * cfg.in_channels
            return 2.0 * cfg.d_model * pdim * 64 * 4096
        n = _numel(dit_mod.dit_specs(cfg))
        return 2.0 * n * 64 * 4096
    cfg = config_lib.for_shape(config_lib.get_config(arch), shape)
    info = config_lib.INPUT_SHAPES[shape]
    n_act = active_params(cfg)
    if info["kind"] == "train":
        tokens = info["global_batch"] * info["seq_len"]
        return 6.0 * n_act * tokens
    if info["kind"] == "prefill":
        tokens = info["global_batch"] * info["seq_len"]
        return 2.0 * n_act * tokens
    tokens = info["global_batch"]  # decode: one token per request
    return 2.0 * n_act * tokens


def run(dryrun_dir: str = "results/dryrun",
        out: str = "results/bench/roofline.json"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        n = rec["n_devices"]
        # per-device HLO flops/bytes from the analyzer x n_devices = global
        flops_g = rec["flops"] * n
        bytes_g = rec["bytes_accessed"] * n
        coll_g = rec["collectives"]["total_bytes"] * n
        terms = analysis.roofline_terms(flops_g, bytes_g, coll_g, n)
        mf = model_flops_for(rec["arch"], rec["shape"])
        hbm_gb = (rec["memory"].get("argument_size_bytes", 0)
                  + rec["memory"].get("temp_size_bytes", 0)
                  + rec["memory"].get("output_size_bytes", 0)
                  - rec["memory"].get("alias_size_bytes", 0)) / 1e9
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_ms": round(terms["compute_s"] * 1e3, 3),
            "memory_ms": round(terms["memory_s"] * 1e3, 3),
            "collective_ms": round(terms["collective_s"] * 1e3, 3),
            "bottleneck": terms["bottleneck"].replace("_s", ""),
            "model_flops": f"{mf:.3e}",
            "model/hlo": round(mf / max(flops_g, 1.0), 3),
            "hbm_gb_per_dev": round(hbm_gb, 2),
        })
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    B.print_table("Roofline terms per (arch x shape x mesh)", rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
