"""Paper Table 3 (FLUX.1-Kontext editing) at CPU scale.

Editing = img2img: start the sampler from a partially-noised reference
image (edit strength tau), run the remaining trajectory under each cache
policy, score PSNR/SSIM vs the uncached edited result (stand-in for the
GEdit Q_* judge scores, which need external models).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as B
from repro.core.cache import CachePolicy
from repro.data import synthetic
from repro.diffusion import schedule


def run(method: str = "dct", title: str = "Table 3 — Kontext-like editing (DCT)",
        out: str = "results/bench/table3.json", tau: float = 0.6):
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    ref_img = synthetic.shapes_batch(jax.random.key(7), B.BATCH,
                                     size=B.IMG_SIZE,
                                     channels=cfg.in_channels)
    noise = jax.random.normal(jax.random.key(8), ref_img.shape)
    x0 = schedule.add_noise(ref_img, noise, tau)

    base = B.run_policy(cfg, full_fn, from_crf_fn, CachePolicy(kind="none"),
                        x0)
    rows = [B.quality_row("full edit (baseline)", base, base["x"],
                          base["wall_s"], base["flops"])]
    for interval in (5, 7, 10):
        for kind in ("fora", "taylorseer", "freqca"):
            pol = CachePolicy(kind=kind, interval=interval, method=method,
                              rho=0.0625, high_order=2)
            res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0)
            rows.append(B.quality_row(f"{kind}(N={interval})", res,
                                      base["x"], base["wall_s"],
                                      base["flops"]))
    B.print_table(title, rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
