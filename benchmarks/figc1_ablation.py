"""Paper Fig 7 / C1: decomposition x prediction-order ablation.

Sweeps {none, fft, dct} x (low_order, high_order) at several intervals;
the paper's finding to validate: (low=reuse/0, high=2) with a real
decomposition dominates; no-decomposition degrades at large N.
"""
from __future__ import annotations

import jax

from benchmarks import common as B
from repro.core.cache import CachePolicy


def run(out: str = "results/bench/figc1.json"):
    cfg, params = B.get_model()
    full_fn, from_crf_fn = B.make_fns(cfg, params)
    x0 = jax.random.normal(jax.random.key(11),
                           (B.BATCH, B.IMG_SIZE, B.IMG_SIZE,
                            cfg.in_channels))
    base = B.run_policy(cfg, full_fn, from_crf_fn, CachePolicy(kind="none"),
                        x0)

    rows = []
    grids = [
        ("none", [(0, 0), (0, 2)]),       # no decomposition: reuse / taylor
        ("fft", [(0, 2), (0, 1), (1, 2), (2, 2), (0, 0)]),
        ("dct", [(0, 2), (0, 1), (1, 2), (2, 2), (0, 0)]),
    ]
    # rho (low-band fraction) sweep at the paper-default orders
    for n in (5, 10):
        for method in ("fft", "dct"):
            for rho in (0.0625, 0.125, 0.25, 0.5):
                pol = CachePolicy(kind="freqca", interval=n, method=method,
                                  rho=rho, low_order=0, high_order=2)
                res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0,
                                   time_it=False)
                res["wall_s"] = 0.0
                row = B.quality_row(f"{method}/rho={rho}/N={n}", res,
                                    base["x"], 1.0, base["flops"])
                row.pop("latency_s")
                row.pop("speed")
                rows.append(row)
    for n in (5, 10):
        for method, orders in grids:
            for lo, hi in orders:
                if method == "none":
                    kind = "fora" if (lo, hi) == (0, 0) else "taylorseer"
                    pol = CachePolicy(kind=kind, interval=n, high_order=hi)
                    name = f"none/({lo},{hi})/N={n}"
                else:
                    pol = CachePolicy(kind="freqca", interval=n,
                                      method=method, rho=0.0625,
                                      low_order=lo, high_order=hi)
                    name = f"{method}/({lo},{hi})/N={n}"
                res = B.run_policy(cfg, full_fn, from_crf_fn, pol, x0,
                                   time_it=False)
                res["wall_s"] = 0.0
                row = B.quality_row(name, res, base["x"], 1.0,
                                    base["flops"])
                row.pop("latency_s")
                row.pop("speed")
                rows.append(row)
    B.print_table("Fig C1 — decomposition x prediction-order ablation",
                  rows)
    B.save_rows(out, rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
