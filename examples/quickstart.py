"""Quickstart: train a small DiT on synthetic shapes, then sample with
FreqCa at 5x scheduled compute saving and compare with the uncached
output.

Cache policies are self-contained objects from the registry
(``repro.core.policies``) — construct them directly and pass them to
the sampler.  (The legacy ``CachePolicy(kind=...)`` spec still resolves
but is deprecated.)

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.configs as config_lib
from repro.core import policies
from repro.diffusion import sampler, schedule
from repro.launch.train import train_dit
from repro.models import dit

print("registered cache policies:", ", ".join(policies.available()))

cfg = config_lib.get_config("dit-small")
params = train_dit(cfg, steps=120, batch=16, ckpt_dir="", size=32)


def full_fn(x, t):
    tb = jnp.full((x.shape[0],), t)
    out = dit.dit_forward(params, x, tb, cfg)
    return out.velocity, out.crf


def from_crf_fn(crf, t):
    tb = jnp.full((crf.shape[0],), t)
    return dit.dit_from_crf(params, crf, tb, cfg, 32, 32)


x0 = jax.random.normal(jax.random.key(0), (4, 32, 32, cfg.in_channels))
ts = schedule.timesteps(50)
crf_shape = (4, (32 // cfg.patch_size) ** 2, cfg.d_model)

full = sampler.sample(full_fn, from_crf_fn, x0, ts,
                      policies.NoCachePolicy(), crf_shape=crf_shape)
pol = policies.FreqCaPolicy(interval=5, method="dct", rho=0.0625)
freqca = sampler.sample(full_fn, from_crf_fn, x0, ts, pol,
                        crf_shape=crf_shape)
err = float(jnp.linalg.norm(freqca.x - full.x) / jnp.linalg.norm(full.x))
print(f"uncached: {int(full.n_full)} full steps; "
      f"freqca: {int(freqca.n_full)} full steps "
      f"({50 / int(freqca.n_full):.2f}x scheduled compute saving)")
print(f"relative output error vs uncached: {err:.4f}")
