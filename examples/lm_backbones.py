"""Assigned-architecture tour: run a reduced variant of every assigned
architecture through one train step and a short greedy decode.

  PYTHONPATH=src python examples/lm_backbones.py
"""
import jax

import repro.configs as config_lib
from repro.launch.train import train_lm
from repro.models import common, transformer
from repro.serving.engine import LMEngine

for arch in config_lib.ASSIGNED:
    cfg = config_lib.reduced(config_lib.get_config(arch))
    print(f"== {arch} ({cfg.family}) ==")
    if cfg.is_encdec:
        _, losses = train_lm(cfg, steps=3, batch=2, seq=32, ckpt_dir="")
        print(f"  3 train steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        continue
    params, losses = train_lm(cfg, steps=3, batch=2, seq=32, ckpt_dir="")
    print(f"  3 train steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if cfg.n_prefix_tokens == 0:
        eng = LMEngine(params, cfg, max_len=16)
        prompt = jax.random.randint(jax.random.key(0), (1, 4), 0,
                                    cfg.vocab_size)
        out = eng.generate(prompt, n_new=6)
        print(f"  decode: {out[0].tolist()}")
