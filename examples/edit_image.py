"""Image editing (FLUX.1-Kontext / Qwen-Image-Edit regime): start from a
partially-noised reference, denoise under FreqCa, measure fidelity vs
the uncached edit.

  PYTHONPATH=src python examples/edit_image.py
"""
import jax
import jax.numpy as jnp

import repro.configs as config_lib
from repro.core import policies
from repro.data import synthetic
from repro.diffusion import sampler, schedule
from repro.launch.train import train_dit
from repro.models import dit

cfg = config_lib.get_config("dit-small")
params = train_dit(cfg, steps=120, batch=16, ckpt_dir="", size=32)


def full_fn(x, t):
    tb = jnp.full((x.shape[0],), t)
    out = dit.dit_forward(params, x, tb, cfg)
    return out.velocity, out.crf


def from_crf_fn(crf, t):
    tb = jnp.full((crf.shape[0],), t)
    return dit.dit_from_crf(params, crf, tb, cfg, 32, 32)


ref = synthetic.shapes_batch(jax.random.key(3), 2, size=32,
                             channels=cfg.in_channels)
noise = jax.random.normal(jax.random.key(4), ref.shape)
tau = 0.6                                   # edit strength
x0 = schedule.add_noise(ref, noise, tau)
ts = schedule.timesteps(50) * tau           # resume from t = tau
crf_shape = (2, (32 // cfg.patch_size) ** 2, cfg.d_model)

full = sampler.sample(full_fn, from_crf_fn, x0, ts,
                      policies.NoCachePolicy(), crf_shape=crf_shape)
fast = sampler.sample(full_fn, from_crf_fn, x0, ts,
                      policies.FreqCaPolicy(interval=5, method="fft"),
                      crf_shape=crf_shape)
err = float(jnp.linalg.norm(fast.x - full.x) / jnp.linalg.norm(full.x))
print(f"edit with freqca: {int(fast.n_full)}/50 full steps, "
      f"rel err vs uncached edit {err:.4f}")
