"""Reproduce the paper's Fig-2 frequency analysis on the bench DiT:
low band = similar but jumpy; high band = less similar but continuous.

  PYTHONPATH=src python examples/freq_analysis.py
"""
from benchmarks import fig2_freq_analysis

if __name__ == "__main__":
    fig2_freq_analysis.run()
