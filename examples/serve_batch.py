"""End-to-end serving driver (the paper's deployment shape): a
mixed-size stream of generation + editing requests through the
continuous-batching FreqCa DiffusionEngine — per-bucket precompiled
executables, age-based batch formation, metrics report.

Requests carry per-request cache policies (freqca / fora / freqca_a
cycling), arrivals follow an open-loop Poisson process, and the client
is four real threads submitting through ``AsyncDiffusionEngine`` —
every submit returns a future immediately and the engine's worker
overlaps the clients (``--clients 0`` would fall back to the
single-thread sync replay baseline).

  PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch import serve

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--requests", "16", "--interval", "5",
                "--steps", "50", "--train-steps", "120", "--batch", "8",
                "--edit-every", "5", "--mixed-policies",
                "--arrival", "poisson", "--rate", "2.0", "--clients", "4"]
    serve.main()
