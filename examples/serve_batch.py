"""End-to-end serving driver (the paper's deployment shape): batched
generation requests through the FreqCa DiffusionEngine, with latency,
speedup, and fidelity report.

  PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch import serve

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--requests", "8", "--interval", "5",
                "--steps", "50", "--train-steps", "120"]
    serve.main()
